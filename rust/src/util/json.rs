//! Minimal JSON reader/writer (offline build: serde is unavailable).
//!
//! Covers the subset needed for `artifacts/manifest.json` and report files:
//! objects, arrays, strings (with escapes), numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            other => Err(anyhow!("expected number, got {other:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(anyhow!("expected string, got {other:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(anyhow!("expected array, got {other:?}")),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(anyhow!("expected object, got {other:?}")),
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Serialize compactly.
    pub fn dumps(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with `indent` spaces per level.
    pub fn pretty(&self, indent: usize) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(indent), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1))),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

/// Convenience constructors.
impl From<f64> for Json {
    fn from(x: f64) -> Self { Json::Num(x) }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self { Json::Num(x as f64) }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self { Json::Str(s.to_string()) }
}
impl From<String> for Json {
    fn from(s: String) -> Self { Json::Str(s) }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self { Json::Bool(b) }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self { Json::Arr(v.into_iter().map(Into::into).collect()) }
}

/// Build an object from (key, value) pairs.
pub fn obj<const N: usize>(pairs: [(&str, Json); N]) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.pos, self.peek()? as char);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            m.insert(k, self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + width).min(self.bytes.len());
                        let chunk = std::str::from_utf8(&self.bytes[start..end])?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let doc = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64().unwrap(), -2500.0);
        let again = Json::parse(&v.dumps()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let doc = r#"{"batch": 64, "params": [["conv1_w", [3,3,1,8]], ["fc_b", [5]]],
                      "exact_test_accuracy": 0.9355}"#;
        let v = Json::parse(doc).unwrap();
        let params = v.get("params").unwrap().as_arr().unwrap();
        assert_eq!(params.len(), 2);
        assert_eq!(params[0].as_arr().unwrap()[0].as_str().unwrap(), "conv1_w");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn pretty_is_parseable() {
        let v = obj([("x", Json::from(vec![1.0, 2.0])), ("y", Json::from("s"))]);
        assert_eq!(Json::parse(&v.pretty(2)).unwrap(), v);
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""é café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é café ☕");
    }

    #[test]
    fn as_usize_validates() {
        assert_eq!(Json::Num(5.0).as_usize().unwrap(), 5);
        assert!(Json::Num(5.5).as_usize().is_err());
        assert!(Json::Num(-1.0).as_usize().is_err());
    }
}
