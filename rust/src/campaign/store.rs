//! Append-only JSONL result store with checkpoint/resume.
//!
//! One line per completed job, written in schedule order by the commit
//! pipeline's single writer. On open, existing rows are parsed and their
//! job keys indexed, so a restarted campaign skips completed scenarios. A
//! torn final line (interrupted mid-write, so no trailing newline) is
//! dropped and its job redone; corruption anywhere else — including an
//! unparseable but newline-*terminated* final line, which an interrupted
//! append can never produce — is a loud error rather than silent data
//! loss. Sharded campaigns coordinate through the sibling
//! [`crate::campaign::lease`] directory; each shard writes its own store
//! of this same format.
//!
//! **Header line.** Stores written by `--sampler adaptive` begin with a
//! schema line (`{"schema":"carbon3d-store/1","sampler":"adaptive",...}`)
//! identified by its `schema` field, so a resume or merge can detect —
//! and loudly refuse — a sampler-mode mismatch: an adaptive store replays
//! its batch plan from the committed rows, which an exhaustive walker
//! would corrupt, and vice versa. Legacy / exhaustive stores carry no
//! header, keeping every pre-existing store byte-stable and resumable.

use std::collections::HashSet;
use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::obj;
use crate::util::Json;

use super::fault;
use super::spec::SamplerMode;

/// Field every row carries to identify its scenario.
pub const KEY_FIELD: &str = "key";

/// Field marking a quarantined-failure row (`true`): the job's
/// evaluation panicked and the row records the panic instead of a
/// result. Failed rows occupy their key (a resume does not redo them
/// unless `--retry-failed` purges them) but never enter the Pareto
/// archive or incumbent state.
pub const FAILED_FIELD: &str = "failed";

/// Whether a row is a quarantined-failure marker rather than a result.
pub fn row_is_failed(row: &Json) -> bool {
    matches!(row.get(FAILED_FIELD), Ok(Json::Bool(true)))
}

/// Schema tag the optional header line carries.
pub const STORE_SCHEMA: &str = "carbon3d-store/1";

/// The JSONL store.
pub struct ResultStore {
    path: PathBuf,
    rows: Vec<Json>,
    keys: HashSet<String>,
    file: File,
    /// Sampler mode recorded in the header line, if the store has one
    /// (adaptive stores always do; exhaustive/legacy stores never do).
    header: Option<SamplerMode>,
}

/// Parse a header line's sampler mode. `None` when the line is a data row
/// (no `schema` field); an error when it claims a schema we don't speak or
/// a sampler we don't know.
fn parse_header(row: &Json) -> Result<Option<SamplerMode>> {
    let Ok(schema) = row.get("schema").and_then(|s| s.as_str().map(str::to_string)) else {
        return Ok(None);
    };
    ensure!(
        schema == STORE_SCHEMA,
        "store header claims schema {schema:?}; this build speaks {STORE_SCHEMA:?}"
    );
    let sampler = row
        .get("sampler")
        .and_then(|s| s.as_str().map(str::to_string))
        .context("store header has no string `sampler`")?;
    match sampler.as_str() {
        "exhaustive" => Ok(Some(SamplerMode::Exhaustive)),
        "adaptive" => {
            let batch = row
                .get("batch")
                .ok()
                .and_then(|b| b.as_usize().ok())
                .context("adaptive store header has no integer `batch`")?;
            ensure!(batch >= 1, "adaptive store header batch must be >= 1, got {batch}");
            Ok(Some(SamplerMode::Adaptive { batch }))
        }
        other => bail!("store header names unknown sampler {other:?}"),
    }
}

/// The header line an adaptive campaign writes as its first store line.
fn header_row(mode: SamplerMode) -> Json {
    match mode.batch() {
        Some(batch) => obj([
            ("schema", Json::from(STORE_SCHEMA)),
            ("sampler", Json::from(mode.name())),
            ("batch", Json::from(batch)),
        ]),
        None => obj([
            ("schema", Json::from(STORE_SCHEMA)),
            ("sampler", Json::from(mode.name())),
        ]),
    }
}

impl ResultStore {
    /// Open (creating parent directories and the file if needed) and index
    /// any rows already present.
    pub fn open(path: &Path) -> Result<Self> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("create store directory {}", dir.display()))?;
            }
        }
        let existing = match std::fs::read_to_string(path) {
            Ok(text) => text,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
            Err(e) => return Err(e).with_context(|| format!("read store {}", path.display())),
        };
        let mut rows = Vec::new();
        let mut keys = HashSet::new();
        let mut header: Option<SamplerMode> = None;
        let mut torn = false;
        // Only a *final* line with no trailing newline can be a torn append
        // (the writer always emits `row\n` in one call). Anything else that
        // fails to parse is corruption and must error loudly — quietly
        // dropping it would silently truncate committed results.
        let ends_with_newline = existing.ends_with('\n');
        let lines: Vec<&str> = existing.lines().filter(|l| !l.trim().is_empty()).collect();
        for (i, line) in lines.iter().enumerate() {
            match Json::parse(line) {
                Ok(row) => {
                    // The header can only be the first line (the writer
                    // emits it before any row); a `schema` field anywhere
                    // else is treated as an ordinary (malformed) row.
                    if i == 0 {
                        if let Some(mode) = parse_header(&row)
                            .with_context(|| format!("store {} header", path.display()))?
                        {
                            header = Some(mode);
                            continue;
                        }
                    }
                    let key = row
                        .get(KEY_FIELD)
                        .and_then(|k| k.as_str().map(str::to_string))
                        .with_context(|| format!("store row {} has no string `key`", i + 1))?;
                    if !keys.insert(key.clone()) {
                        bail!("store {} has duplicate key {key:?}", path.display());
                    }
                    rows.push(row);
                }
                Err(e) if i + 1 == lines.len() && !ends_with_newline => {
                    // Torn tail from an interrupted append: drop it; the
                    // campaign will redo that job. Routed through the obs
                    // event API: warns on stderr, bumps the
                    // `store.torn_append` counter (countable in tests), and
                    // lands in the trace sidecar when tracing is on.
                    crate::obs::warn_event(
                        "store.torn_append",
                        &format!("store {}: ignoring torn final line ({e:#})", path.display()),
                        &[
                            ("store", Json::from(path.display().to_string())),
                            ("error", Json::from(format!("{e:#}"))),
                        ],
                    );
                    torn = true;
                }
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!(
                            "store {} row {} corrupt (not a torn append tail); \
                             refusing to resume over damaged results",
                            path.display(),
                            i + 1
                        )
                    })
                }
            }
        }
        if torn {
            // Drop the torn bytes without risking the committed prefix:
            // write the good rows to a sibling temp file, then atomically
            // rename it over the store. The common (untorn) path never
            // rewrites anything.
            let tmp = path.with_extension("jsonl.tmp");
            let mut f = File::create(&tmp)
                .with_context(|| format!("create {}", tmp.display()))?;
            if let Some(mode) = header {
                writeln!(f, "{}", header_row(mode).dumps())
                    .with_context(|| format!("rewrite store header {}", tmp.display()))?;
            }
            for row in &rows {
                writeln!(f, "{}", row.dumps())
                    .with_context(|| format!("rewrite store {}", tmp.display()))?;
            }
            f.flush()?;
            drop(f);
            std::fs::rename(&tmp, path)
                .with_context(|| format!("replace store {}", path.display()))?;
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("open store {}", path.display()))?;
        Ok(Self { path: path.to_path_buf(), rows, keys, file, header })
    }

    /// The sampler mode recorded in the store's header line, if any
    /// (legacy and exhaustive stores have no header).
    pub fn sampler_header(&self) -> Option<SamplerMode> {
        self.header
    }

    /// Verify this store may be driven by a campaign in `mode`, writing
    /// the header line when an adaptive campaign starts a fresh store.
    ///
    /// The rules, all loud (a wrong walker would silently produce a store
    /// whose bytes depend on which mode wrote which rows):
    /// - exhaustive over a headerless store: fine (the legacy format);
    /// - exhaustive over an adaptive store, or adaptive over a store that
    ///   already has rows but no header: refused;
    /// - adaptive over an empty headerless store: writes the header;
    /// - header present: the mode (including the batch size, which fixes
    ///   the replayed batch plan) must match exactly.
    pub fn ensure_sampler(&mut self, mode: SamplerMode) -> Result<()> {
        match self.header {
            None => match mode {
                SamplerMode::Exhaustive => Ok(()),
                SamplerMode::Adaptive { .. } => {
                    ensure!(
                        self.rows.is_empty(),
                        "store {} has {} rows but no sampler header: it was written by an \
                         exhaustive campaign and cannot be resumed with --sampler adaptive \
                         (the adaptive batch replay would not match the committed rows)",
                        self.path.display(),
                        self.rows.len()
                    );
                    writeln!(self.file, "{}", header_row(mode).dumps())
                        .with_context(|| format!("write header to {}", self.path.display()))?;
                    self.file.flush()?;
                    self.header = Some(mode);
                    Ok(())
                }
            },
            Some(have) => {
                ensure!(
                    have == mode,
                    "store {} was written with sampler {}{}; this run asked for {}{} — \
                     rerun with the matching --sampler flags or use a fresh store",
                    self.path.display(),
                    have.name(),
                    have.batch().map(|b| format!(" (batch {b})")).unwrap_or_default(),
                    mode.name(),
                    mode.batch().map(|b| format!(" (batch {b})")).unwrap_or_default(),
                );
                Ok(())
            }
        }
    }

    /// Has a row for this job key already been committed?
    pub fn contains(&self, key: &str) -> bool {
        self.keys.contains(key)
    }

    /// Append one result row (must carry a unique `key`) and flush.
    pub fn append(&mut self, row: Json) -> Result<()> {
        let key = row
            .get(KEY_FIELD)
            .and_then(|k| k.as_str().map(str::to_string))
            .context("result row has no string `key`")?;
        if !self.keys.insert(key.clone()) {
            bail!("duplicate result for job {key:?}");
        }
        // One `line\n` buffer per row: a crash mid-write leaves a torn,
        // newline-less tail that the reopen path drops (fault site
        // `store.append` tears exactly here). Injected io-errors fire
        // before any bytes land, so the retry rewrites the full line.
        let line = format!("{}\n", row.dumps());
        let file = &mut self.file;
        fault::retry_io("store.append", || -> std::io::Result<()> {
            fault::write_all("store.append", file, line.as_bytes())?;
            file.flush()
        })
        .with_context(|| format!("append to store {}", self.path.display()))?;
        self.rows.push(row);
        Ok(())
    }

    /// Drop all quarantined-failure rows (`--retry-failed`): rewrite the
    /// store without them via a sibling temp file + atomic rename, so
    /// the jobs become eligible to rerun. Returns how many were purged.
    pub fn purge_failed(&mut self) -> Result<usize> {
        let failed: Vec<String> = self
            .rows
            .iter()
            .filter(|r| row_is_failed(r))
            .filter_map(|r| r.get(KEY_FIELD).ok().and_then(|k| k.as_str().ok()).map(str::to_string))
            .collect();
        if failed.is_empty() {
            return Ok(0);
        }
        self.rows.retain(|r| !row_is_failed(r));
        for key in &failed {
            self.keys.remove(key);
        }
        let tmp = self.path.with_extension("jsonl.tmp");
        let mut f =
            File::create(&tmp).with_context(|| format!("create {}", tmp.display()))?;
        if let Some(mode) = self.header {
            writeln!(f, "{}", header_row(mode).dumps())
                .with_context(|| format!("rewrite store header {}", tmp.display()))?;
        }
        for row in &self.rows {
            writeln!(f, "{}", row.dumps())
                .with_context(|| format!("rewrite store {}", tmp.display()))?;
        }
        f.flush()?;
        drop(f);
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("replace store {}", self.path.display()))?;
        // The old append handle points at the renamed-over inode; reopen.
        self.file = OpenOptions::new()
            .append(true)
            .open(&self.path)
            .with_context(|| format!("reopen store {}", self.path.display()))?;
        crate::obs::warn_event(
            "store.retry_failed",
            &format!(
                "store {}: purged {} failed row(s) for retry ({})",
                self.path.display(),
                failed.len(),
                failed.join(", ")
            ),
            &[("count", Json::from(failed.len() as f64))],
        );
        Ok(failed.len())
    }

    /// All committed rows, in file order.
    pub fn rows(&self) -> &[Json] {
        &self.rows
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "carbon3d-store-{}-{name}.jsonl",
            std::process::id()
        ))
    }

    fn row(key: &str, x: f64) -> Json {
        obj([("key", Json::from(key)), ("x", Json::from(x))])
    }

    #[test]
    fn append_then_reopen_indexes_keys() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = ResultStore::open(&path).unwrap();
            assert!(s.is_empty());
            s.append(row("a", 1.0)).unwrap();
            s.append(row("b", 2.0)).unwrap();
        }
        let s = ResultStore::open(&path).unwrap();
        assert_eq!(s.len(), 2);
        assert!(s.contains("a") && s.contains("b") && !s.contains("c"));
        assert_eq!(s.rows()[1].get("x").unwrap().as_f64().unwrap(), 2.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn duplicate_key_rejected() {
        let path = tmp("dup");
        let _ = std::fs::remove_file(&path);
        let mut s = ResultStore::open(&path).unwrap();
        s.append(row("a", 1.0)).unwrap();
        assert!(s.append(row("a", 9.0)).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_final_line_is_dropped_and_redone() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = ResultStore::open(&path).unwrap();
            s.append(row("a", 1.0)).unwrap();
        }
        // Simulate a crash mid-append.
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        write!(f, "{{\"key\": \"b\", \"x\":").unwrap();
        drop(f);
        let torn_before = crate::obs::metrics().counter("store.torn_append");
        let s = ResultStore::open(&path).unwrap();
        assert_eq!(s.len(), 1);
        assert!(!s.contains("b"));
        // The recovery is an obs event now: countable with tracing off.
        assert!(crate::obs::metrics().counter("store.torn_append") > torn_before);
        // The torn bytes are gone from disk after reopen.
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mid_file_corruption_is_an_error() {
        let path = tmp("corrupt");
        let _ = std::fs::remove_file(&path);
        std::fs::write(&path, "not json\n{\"key\": \"a\", \"x\": 1}\n").unwrap();
        assert!(ResultStore::open(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn newline_terminated_garbage_tail_is_an_error_not_a_truncation() {
        // A final line that fails to parse but IS newline-terminated cannot
        // be a torn append (appends write `row\n` atomically from the
        // store's perspective) — treat it as corruption, never drop it.
        let path = tmp("garbage-tail");
        let _ = std::fs::remove_file(&path);
        std::fs::write(&path, "{\"key\": \"a\", \"x\": 1}\nnot json\n").unwrap();
        let err = ResultStore::open(&path).err().expect("open must refuse garbage tail");
        assert!(format!("{err:#}").contains("row 2"), "{err:#}");
        // The damaged file is left untouched for inspection.
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn adaptive_header_roundtrips_and_survives_torn_tail() {
        let path = tmp("header");
        let _ = std::fs::remove_file(&path);
        let mode = SamplerMode::Adaptive { batch: 4 };
        {
            let mut s = ResultStore::open(&path).unwrap();
            assert_eq!(s.sampler_header(), None);
            s.ensure_sampler(mode).unwrap();
            s.append(row("a", 1.0)).unwrap();
        }
        // Reopen: header parsed, not counted as a row.
        {
            let s = ResultStore::open(&path).unwrap();
            assert_eq!(s.sampler_header(), Some(mode));
            assert_eq!(s.len(), 1);
            assert!(s.contains("a"));
        }
        // A torn final line is dropped and the rewrite keeps the header
        // as the first line.
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        write!(f, "{{\"key\": \"b\", \"x\":").unwrap();
        drop(f);
        let s = ResultStore::open(&path).unwrap();
        assert_eq!(s.sampler_header(), Some(mode));
        assert_eq!(s.len(), 1);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("{\"schema\":\"carbon3d-store/1\""), "{text}");
        assert_eq!(text.lines().count(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn ensure_sampler_refuses_mixed_modes() {
        let path = tmp("mixed");
        let _ = std::fs::remove_file(&path);
        // Adaptive resume over a headerless store with rows: refused.
        {
            let mut s = ResultStore::open(&path).unwrap();
            s.ensure_sampler(SamplerMode::Exhaustive).unwrap();
            s.append(row("a", 1.0)).unwrap();
            let err = s.ensure_sampler(SamplerMode::Adaptive { batch: 4 }).unwrap_err();
            assert!(format!("{err:#}").contains("--sampler adaptive"), "{err:#}");
        }
        // Exhaustive (or different-batch adaptive) over an adaptive store:
        // refused, naming both modes.
        let adaptive = tmp("mixed-adaptive");
        let _ = std::fs::remove_file(&adaptive);
        {
            let mut s = ResultStore::open(&adaptive).unwrap();
            s.ensure_sampler(SamplerMode::Adaptive { batch: 4 }).unwrap();
        }
        let mut s = ResultStore::open(&adaptive).unwrap();
        let err = s.ensure_sampler(SamplerMode::Exhaustive).unwrap_err();
        assert!(format!("{err:#}").contains("adaptive (batch 4)"), "{err:#}");
        let err = s.ensure_sampler(SamplerMode::Adaptive { batch: 8 }).unwrap_err();
        assert!(format!("{err:#}").contains("batch 8"), "{err:#}");
        // The matching mode is accepted and idempotent.
        s.ensure_sampler(SamplerMode::Adaptive { batch: 4 }).unwrap();
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&adaptive);
    }

    #[test]
    fn unknown_store_schema_is_a_loud_error() {
        let path = tmp("schema");
        let _ = std::fs::remove_file(&path);
        std::fs::write(&path, "{\"schema\": \"carbon3d-store/9\", \"sampler\": \"adaptive\"}\n")
            .unwrap();
        let err = ResultStore::open(&path).unwrap_err();
        assert!(format!("{err:#}").contains("carbon3d-store/1"), "{err:#}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn purge_failed_frees_the_key_for_retry() {
        let path = tmp("purge-failed");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = ResultStore::open(&path).unwrap();
            s.append(row("a", 1.0)).unwrap();
            s.append(obj([
                ("key", Json::from("b")),
                ("failed", Json::from(true)),
                ("error", Json::from("injected panic")),
            ]))
            .unwrap();
            s.append(row("c", 3.0)).unwrap();
            assert!(row_is_failed(&s.rows()[1]));
            assert_eq!(s.purge_failed().unwrap(), 1);
            assert_eq!(s.len(), 2);
            assert!(!s.contains("b"), "purged key is free again");
            assert_eq!(s.purge_failed().unwrap(), 0, "idempotent");
            // The reopened append handle still works.
            s.append(row("b", 2.0)).unwrap();
        }
        let s = ResultStore::open(&path).unwrap();
        assert_eq!(s.len(), 3);
        assert!(s.contains("b") && !row_is_failed(&s.rows()[2]));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn injected_io_error_on_append_is_retried_transparently() {
        let path = tmp("fault-append");
        let _ = std::fs::remove_file(&path);
        let _guard = fault::test_guard();
        let mut s = ResultStore::open(&path).unwrap();
        fault::arm(vec![fault::FaultRule {
            site: "store.append".into(),
            nth: 1,
            kind: fault::FaultKind::IoError,
        }]);
        let before = crate::obs::metrics().counter("io_retries");
        let r = s.append(row("a", 1.0));
        fault::disarm();
        r.unwrap();
        assert!(crate::obs::metrics().counter("io_retries") > before);
        drop(s);
        let s = ResultStore::open(&path).unwrap();
        assert_eq!(s.len(), 1, "the retried append wrote exactly one intact row");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rows_without_keys_are_rejected() {
        let path = tmp("nokey");
        let _ = std::fs::remove_file(&path);
        let mut s = ResultStore::open(&path).unwrap();
        assert!(s.append(obj([("x", Json::from(1.0))])).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
