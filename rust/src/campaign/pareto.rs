//! Cross-scenario Pareto archive: every committed campaign row is a point
//! in (carbon, task delay, accuracy drop) space — where "carbon" is the
//! campaign objective's metric (embodied gCO2, or lifetime gCO2 for the
//! lifetime objectives) — and the archive keeps the non-dominated set
//! across ALL scenarios plus per-node and per-workload aggregate summaries.
//!
//! The archive is **incremental**: the scheduler calls [`CampaignArchive::
//! insert_row`] as each row commits, so the front is maintained in O(|front|)
//! per insert instead of recomputed O(n^2) from the full store. It is also
//! **checkpointed** alongside the JSONL store (a small sidecar JSON with the
//! front indices); [`CampaignArchive::load_or_rebuild`] restores it on
//! resume and falls back to an incremental rebuild whenever the sidecar is
//! missing, stale, or corrupt — the store rows remain the source of truth.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::obj;
use crate::util::{table, Json, Table};

/// Which carbon metric spans the archive's first objective axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CarbonAxis {
    /// Embodied gCO2 (the paper's view).
    Embodied,
    /// Embodied + lifetime operational gCO2.
    Lifetime,
}

impl CarbonAxis {
    pub fn name(&self) -> &'static str {
        match self {
            CarbonAxis::Embodied => "embodied",
            CarbonAxis::Lifetime => "lifetime",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "embodied" => Some(CarbonAxis::Embodied),
            "lifetime" => Some(CarbonAxis::Lifetime),
            _ => None,
        }
    }
}

/// One campaign result as an objective-space point (all minimized).
#[derive(Debug, Clone)]
pub struct ArchivePoint {
    pub key: String,
    pub model: String,
    pub node: String,
    pub mult: String,
    pub carbon_g: f64,
    /// Embodied + lifetime operational carbon; equals `carbon_g` for rows
    /// written before lifetime accounting existed.
    pub lifetime_gco2: f64,
    pub delay_s: f64,
    pub drop_pct: f64,
    pub cdp: f64,
}

impl ArchivePoint {
    fn from_row(row: &Json) -> Result<Self> {
        let s = |k: &str| -> Result<String> {
            row.get(k).and_then(|v| v.as_str().map(str::to_string)).context(format!("field {k}"))
        };
        let f = |k: &str| -> Result<f64> {
            row.get(k).and_then(|v| v.as_f64()).context(format!("field {k}"))
        };
        let carbon_g = f("carbon_g")?;
        Ok(Self {
            key: s("key")?,
            model: s("model")?,
            node: s("node")?,
            mult: s("mult")?,
            carbon_g,
            lifetime_gco2: f("lifetime_gco2").unwrap_or(carbon_g),
            delay_s: f("delay_s")?,
            drop_pct: f("drop_pct")?,
            cdp: f("cdp")?,
        })
    }

    fn carbon_on(&self, axis: CarbonAxis) -> f64 {
        match axis {
            CarbonAxis::Embodied => self.carbon_g,
            CarbonAxis::Lifetime => self.lifetime_gco2,
        }
    }
}

/// 3-objective dominance (<= everywhere, < somewhere; minimize all).
fn dominates(axis: CarbonAxis, a: &ArchivePoint, b: &ArchivePoint) -> bool {
    let (ca, cb) = (a.carbon_on(axis), b.carbon_on(axis));
    let le = ca <= cb && a.delay_s <= b.delay_s && a.drop_pct <= b.drop_pct;
    let lt = ca < cb || a.delay_s < b.delay_s || a.drop_pct < b.drop_pct;
    le && lt
}

/// Grouping axis for aggregate summaries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupBy {
    Node,
    Model,
}

/// The archive: all points plus the indices of the cross-scenario front.
#[derive(Debug, Clone)]
pub struct CampaignArchive {
    pub axis: CarbonAxis,
    pub points: Vec<ArchivePoint>,
    /// Indices into `points` on the (carbon, delay, drop) Pareto front,
    /// in ascending insertion (store) order.
    pub front: Vec<usize>,
}

impl CampaignArchive {
    /// An empty archive over the given carbon axis.
    pub fn new(axis: CarbonAxis) -> Self {
        Self { axis, points: Vec::new(), front: Vec::new() }
    }

    /// Insert one point, updating the front incrementally. Returns whether
    /// the point landed on the front. Checking the new point against the
    /// current front members alone is sufficient: any dominator of the new
    /// point is itself dominated only by front members, and dominance is
    /// transitive.
    pub fn insert(&mut self, p: ArchivePoint) -> bool {
        let axis = self.axis;
        let dominated = self.front.iter().any(|&j| dominates(axis, &self.points[j], &p));
        let idx = self.points.len();
        if !dominated {
            let points = &self.points;
            self.front.retain(|&j| !dominates(axis, &p, &points[j]));
            self.front.push(idx);
        }
        self.points.push(p);
        !dominated
    }

    /// Parse and insert one committed store row.
    pub fn insert_row(&mut self, row: &Json) -> Result<bool> {
        let p = ArchivePoint::from_row(row)
            .with_context(|| format!("store row {}", self.points.len() + 1))?;
        Ok(self.insert(p))
    }

    /// Build from committed store rows on the embodied axis (the legacy
    /// full-recompute entry point; kept O(n^2) and independent of the
    /// incremental path so tests can pit one against the other).
    pub fn from_rows(rows: &[Json]) -> Result<Self> {
        Self::from_rows_on(rows, CarbonAxis::Embodied)
    }

    /// Full O(n^2) recompute on an explicit axis.
    pub fn from_rows_on(rows: &[Json], axis: CarbonAxis) -> Result<Self> {
        let points: Vec<ArchivePoint> = rows
            .iter()
            .enumerate()
            .map(|(i, r)| ArchivePoint::from_row(r).with_context(|| format!("store row {}", i + 1)))
            .collect::<Result<_>>()?;
        let front = (0..points.len())
            .filter(|&i| {
                points
                    .iter()
                    .enumerate()
                    .all(|(j, other)| j == i || !dominates(axis, other, &points[i]))
            })
            .collect();
        Ok(Self { axis, points, front })
    }

    /// Stream all rows through the incremental path.
    pub fn from_rows_incremental(rows: &[Json], axis: CarbonAxis) -> Result<Self> {
        let mut arch = Self::new(axis);
        for row in rows {
            arch.insert_row(row)?;
        }
        Ok(arch)
    }

    /// Sidecar path for a store at `store_path` (e.g. `campaign.jsonl` ->
    /// `campaign.front.json`).
    pub fn checkpoint_path(store_path: &Path) -> PathBuf {
        store_path.with_extension("front.json")
    }

    /// The checkpoint document: enough to validate freshness and restore
    /// the front without re-running dominance checks.
    pub fn checkpoint(&self) -> Json {
        obj([
            ("axis", Json::from(self.axis.name())),
            ("n_points", Json::from(self.points.len() as f64)),
            (
                "front",
                Json::Arr(self.front.iter().map(|&i| Json::from(i as f64)).collect()),
            ),
        ])
    }

    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.checkpoint().dumps())
            .with_context(|| format!("write archive checkpoint {}", path.display()))
    }

    /// Restore from a checkpoint if it matches the store (same axis, same
    /// row count, well-formed front); otherwise rebuild incrementally from
    /// the rows. Never fails because of a bad sidecar — the store is the
    /// source of truth and the checkpoint is just a warm start.
    pub fn load_or_rebuild(rows: &[Json], axis: CarbonAxis, ckpt_path: &Path) -> Result<Self> {
        if let Some(arch) = Self::try_restore(rows, axis, ckpt_path) {
            return Ok(arch);
        }
        Self::from_rows_incremental(rows, axis)
    }

    fn try_restore(rows: &[Json], axis: CarbonAxis, ckpt_path: &Path) -> Option<Self> {
        let text = std::fs::read_to_string(ckpt_path).ok()?;
        let ck = Json::parse(&text).ok()?;
        let ck_axis = CarbonAxis::from_name(ck.get("axis").ok()?.as_str().ok()?)?;
        if ck_axis != axis {
            return None;
        }
        let n = ck.get("n_points").ok()?.as_usize().ok()?;
        if n != rows.len() {
            return None; // stale: rows were appended since the checkpoint
        }
        let mut front = Vec::new();
        let mut prev: Option<usize> = None;
        for v in ck.get("front").ok()?.as_arr().ok()? {
            let i = v.as_usize().ok()?;
            if i >= n || prev.is_some_and(|p| p >= i) {
                return None; // malformed: out of range or not ascending
            }
            front.push(i);
            prev = Some(i);
        }
        let points: Vec<ArchivePoint> =
            rows.iter().map(ArchivePoint::from_row).collect::<Result<_>>().ok()?;
        Some(Self { axis, points, front })
    }

    /// The cross-scenario Pareto front as a printable table.
    pub fn pareto_table(&self) -> Table {
        let mut t = Table::new(vec![
            "scenario", "mult", "carbon_g", "lifetime_g", "delay_ms", "drop_pp", "cdp",
        ]);
        for &i in &self.front {
            let p = &self.points[i];
            t.row(vec![
                p.key.clone(),
                p.mult.clone(),
                table::fmt(p.carbon_g),
                table::fmt(p.lifetime_gco2),
                format!("{:.3}", p.delay_s * 1e3),
                format!("{:.2}", p.drop_pct),
                format!("{:.4}", p.cdp),
            ]);
        }
        t
    }

    /// Aggregate summary per node or per workload: scenario count, how many
    /// sit on the cross-scenario front, carbon/cdp extremes and means.
    pub fn aggregate_table(&self, by: GroupBy) -> Table {
        let label = match by {
            GroupBy::Node => "node",
            GroupBy::Model => "model",
        };
        let mut groups: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (i, p) in self.points.iter().enumerate() {
            let g = match by {
                GroupBy::Node => p.node.clone(),
                GroupBy::Model => p.model.clone(),
            };
            groups.entry(g).or_default().push(i);
        }
        let mut t = Table::new(vec![
            label, "jobs", "on_front", "min_carbon_g", "mean_carbon_g", "best_cdp", "min_delay_ms",
        ]);
        for (g, idxs) in &groups {
            let carbons: Vec<f64> = idxs.iter().map(|&i| self.points[i].carbon_g).collect();
            let min_c = carbons.iter().cloned().fold(f64::INFINITY, f64::min);
            let mean_c = carbons.iter().sum::<f64>() / carbons.len() as f64;
            let best_cdp =
                idxs.iter().map(|&i| self.points[i].cdp).fold(f64::INFINITY, f64::min);
            let min_delay =
                idxs.iter().map(|&i| self.points[i].delay_s).fold(f64::INFINITY, f64::min);
            let on_front = idxs.iter().filter(|&&i| self.front.contains(&i)).count();
            t.row(vec![
                g.clone(),
                idxs.len().to_string(),
                on_front.to_string(),
                table::fmt(min_c),
                table::fmt(mean_c),
                format!("{:.4}", best_cdp),
                format!("{:.3}", min_delay * 1e3),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;
    use crate::util::Rng;

    fn row(key: &str, model: &str, node: &str, c: f64, d: f64, a: f64) -> Json {
        obj([
            ("key", Json::from(key)),
            ("model", Json::from(model)),
            ("node", Json::from(node)),
            ("mult", Json::from("M")),
            ("carbon_g", Json::from(c)),
            ("delay_s", Json::from(d)),
            ("drop_pct", Json::from(a)),
            ("cdp", Json::from(c * d)),
        ])
    }

    fn row_lifetime(key: &str, c: f64, life: f64, d: f64, a: f64) -> Json {
        obj([
            ("key", Json::from(key)),
            ("model", Json::from("m")),
            ("node", Json::from("14nm")),
            ("mult", Json::from("M")),
            ("carbon_g", Json::from(c)),
            ("lifetime_gco2", Json::from(life)),
            ("delay_s", Json::from(d)),
            ("drop_pct", Json::from(a)),
            ("cdp", Json::from(c * d)),
        ])
    }

    #[test]
    fn front_excludes_dominated_points() {
        let rows = vec![
            row("a", "vgg16", "14nm", 10.0, 1.0, 1.0),
            row("b", "vgg16", "14nm", 12.0, 2.0, 1.5), // dominated by a
            row("c", "vgg16", "7nm", 8.0, 3.0, 1.0),   // trades delay for carbon
            row("d", "vgg16", "7nm", 11.0, 1.0, 0.5),  // trades carbon for drop
        ];
        let arch = CampaignArchive::from_rows(&rows).unwrap();
        assert_eq!(arch.front, vec![0, 2, 3]);
    }

    #[test]
    fn duplicate_points_both_survive() {
        // Equal points do not dominate each other (no strict improvement).
        let rows = vec![
            row("a", "m", "14nm", 1.0, 1.0, 1.0),
            row("b", "m", "14nm", 1.0, 1.0, 1.0),
        ];
        let arch = CampaignArchive::from_rows(&rows).unwrap();
        assert_eq!(arch.front.len(), 2);
    }

    #[test]
    fn aggregates_group_and_count() {
        let rows = vec![
            row("a", "vgg16", "14nm", 10.0, 1.0, 1.0),
            row("b", "resnet50", "14nm", 20.0, 2.0, 1.0),
            row("c", "vgg16", "7nm", 8.0, 3.0, 1.0),
        ];
        let arch = CampaignArchive::from_rows(&rows).unwrap();
        let t = arch.aggregate_table(GroupBy::Node);
        assert_eq!(t.n_rows(), 2); // 14nm, 7nm
        let t = arch.aggregate_table(GroupBy::Model);
        assert_eq!(t.n_rows(), 2); // vgg16, resnet50
    }

    #[test]
    fn missing_fields_error_with_row_number() {
        let rows = vec![obj([("key", Json::from("a"))])];
        let e = CampaignArchive::from_rows(&rows).unwrap_err();
        assert!(format!("{e:#}").contains("store row 1"), "{e:#}");
    }

    /// A pseudo-random row set with plenty of dominance structure (values
    /// drawn from a small menu so ties and duplicates occur too).
    fn random_rows(rng: &mut Rng, n: usize) -> Vec<Json> {
        let menu = [1.0, 2.0, 3.0, 5.0, 8.0];
        (0..n)
            .map(|i| {
                row(
                    &format!("k{i}"),
                    "m",
                    "14nm",
                    *rng.choice(&menu),
                    *rng.choice(&menu),
                    *rng.choice(&menu),
                )
            })
            .collect()
    }

    fn front_keys(arch: &CampaignArchive) -> Vec<String> {
        let mut ks: Vec<String> =
            arch.front.iter().map(|&i| arch.points[i].key.clone()).collect();
        ks.sort();
        ks
    }

    #[test]
    fn streaming_matches_full_recompute() {
        // Property: for many random row sets, the incremental archive's
        // front is exactly the full-recompute front (same indices).
        let mut rng = Rng::new(0xA5C4DE);
        for n in [0usize, 1, 2, 7, 20, 50] {
            let rows = random_rows(&mut rng, n);
            let full = CampaignArchive::from_rows(&rows).unwrap();
            let inc =
                CampaignArchive::from_rows_incremental(&rows, CarbonAxis::Embodied).unwrap();
            assert_eq!(inc.front, full.front, "n={n}");
            assert_eq!(inc.points.len(), full.points.len());
        }
    }

    #[test]
    fn front_membership_is_insert_order_independent() {
        // Property: permuting the insertion order never changes *which*
        // scenarios are on the front (indices shift, the key set must not).
        let mut rng = Rng::new(0xF00D);
        for trial in 0..10 {
            let rows = random_rows(&mut rng, 16);
            let base = CampaignArchive::from_rows_incremental(&rows, CarbonAxis::Embodied).unwrap();
            let mut perm = rows.clone();
            rng.shuffle(&mut perm);
            let shuffled =
                CampaignArchive::from_rows_incremental(&perm, CarbonAxis::Embodied).unwrap();
            assert_eq!(front_keys(&base), front_keys(&shuffled), "trial {trial}");
        }
    }

    #[test]
    fn insert_reports_front_membership() {
        let mut arch = CampaignArchive::new(CarbonAxis::Embodied);
        assert!(arch.insert_row(&row("a", "m", "14nm", 10.0, 1.0, 1.0)).unwrap());
        // Dominated by a -> not on the front.
        assert!(!arch.insert_row(&row("b", "m", "14nm", 12.0, 2.0, 1.5)).unwrap());
        // Dominates a -> replaces it.
        assert!(arch.insert_row(&row("c", "m", "14nm", 9.0, 0.5, 0.5)).unwrap());
        assert_eq!(arch.front, vec![2]);
        assert_eq!(arch.points.len(), 3);
    }

    #[test]
    fn lifetime_axis_orders_fronts_differently() {
        // Point a: low embodied, high lifetime. Point b: the reverse.
        // Each axis must pick its own winner.
        let rows = vec![
            row_lifetime("a", 5.0, 100.0, 1.0, 1.0),
            row_lifetime("b", 8.0, 40.0, 1.0, 1.0),
        ];
        let emb = CampaignArchive::from_rows_on(&rows, CarbonAxis::Embodied).unwrap();
        let life = CampaignArchive::from_rows_on(&rows, CarbonAxis::Lifetime).unwrap();
        assert_eq!(emb.front, vec![0]);
        assert_eq!(life.front, vec![1]);
        // And rows without the lifetime field fall back to embodied carbon.
        let legacy = vec![row("x", "m", "14nm", 3.0, 1.0, 1.0)];
        let arch = CampaignArchive::from_rows_on(&legacy, CarbonAxis::Lifetime).unwrap();
        assert_eq!(arch.points[0].lifetime_gco2, 3.0);
    }

    #[test]
    fn checkpoint_roundtrip_and_staleness() {
        let mut rng = Rng::new(0xCAFE);
        let rows = random_rows(&mut rng, 12);
        let arch = CampaignArchive::from_rows_incremental(&rows, CarbonAxis::Embodied).unwrap();
        let path = std::env::temp_dir().join(format!(
            "carbon3d-pareto-ckpt-{}.front.json",
            std::process::id()
        ));
        arch.save_checkpoint(&path).unwrap();

        // Fresh checkpoint restores the exact front.
        let restored =
            CampaignArchive::load_or_rebuild(&rows, CarbonAxis::Embodied, &path).unwrap();
        assert_eq!(restored.front, arch.front);

        // Stale checkpoint (more rows than it covers) -> rebuilt, not trusted.
        let mut more = rows.clone();
        more.push(row("extra", "m", "14nm", 0.5, 0.5, 0.5));
        let rebuilt =
            CampaignArchive::load_or_rebuild(&more, CarbonAxis::Embodied, &path).unwrap();
        let full = CampaignArchive::from_rows(&more).unwrap();
        assert_eq!(rebuilt.front, full.front);

        // Axis mismatch -> rebuilt on the requested axis.
        let other = CampaignArchive::load_or_rebuild(&rows, CarbonAxis::Lifetime, &path).unwrap();
        assert_eq!(other.axis, CarbonAxis::Lifetime);

        // Corrupt checkpoint -> rebuilt.
        std::fs::write(&path, "not json at all").unwrap();
        let rebuilt2 =
            CampaignArchive::load_or_rebuild(&rows, CarbonAxis::Embodied, &path).unwrap();
        assert_eq!(rebuilt2.front, arch.front);

        // Missing checkpoint -> rebuilt.
        let _ = std::fs::remove_file(&path);
        let rebuilt3 =
            CampaignArchive::load_or_rebuild(&rows, CarbonAxis::Embodied, &path).unwrap();
        assert_eq!(rebuilt3.front, arch.front);
    }
}
