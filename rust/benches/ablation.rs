//! Bench ABLATION: design choices DESIGN.md calls out.
//!
//!  A. CDP scalarization vs true Pareto (NSGA-style front) — what does the
//!     scalar objective give up?
//!  B. Poisson vs Murphy yield — sensitivity of the carbon ranking.
//!  C. 3D vertical bandwidth sweep — how much of the 3D delay win comes
//!     from the interconnect model.
//!  D. FPS-floor penalty strength — constraint-handling robustness.

use carbon3d::approx::{library, EXACT_ID};
use carbon3d::area::die::Integration;
use carbon3d::area::TechNode;
use carbon3d::carbon::yield_model::{die_yield, die_yield_murphy};
use carbon3d::coordinator::ga_appx_cdp;
use carbon3d::dataflow::arch::AccelConfig;
use carbon3d::dataflow::mapper::map_network;
use carbon3d::dataflow::workloads::workload;
use carbon3d::ga::fitness::FitnessCtx;
use carbon3d::ga::nsga::pareto_front;
use carbon3d::ga::{GaParams, SearchSpace};
use carbon3d::util::Rng;

fn main() {
    let lib = library();
    let w = workload("vgg16").unwrap();

    // ---- A. scalar CDP vs Pareto front ------------------------------------
    println!("== A. CDP scalarization vs Pareto front (vgg16@14nm, δ=3%) ==");
    let mut ctx = FitnessCtx::new(&w, TechNode::N14, Integration::ThreeD, &lib, None);
    let space = SearchSpace::standard((0..lib.len()).collect());
    let mut rng = Rng::new(77);
    let samples: Vec<_> = (0..600).map(|_| space.sample(&mut rng)).collect();
    let evals: Vec<_> = samples.iter().map(|c| ctx.eval(c)).collect();
    let pts: Vec<(f64, f64)> = evals.iter().map(|e| (e.carbon_g, e.delay_s)).collect();
    let front = pareto_front(&pts);
    let ga = ga_appx_cdp(&w, TechNode::N14, &lib, 3.0, None, GaParams::default());
    // Is the GA's CDP optimum on (or near) the sampled Pareto front?
    let best_front_cdp = front
        .iter()
        .map(|&i| evals[i].cdp)
        .fold(f64::INFINITY, f64::min);
    println!(
        "sampled front size {} of {}; best front CDP {:.4}; GA CDP {:.4} ({:.1}% of front best)",
        front.len(),
        samples.len(),
        best_front_cdp,
        ga.best_eval.cdp,
        ga.best_eval.cdp / best_front_cdp * 100.0
    );

    // ---- B. yield model sensitivity ----------------------------------------
    println!("\n== B. Poisson vs Murphy yield (carbon ranking stability) ==");
    for node in [TechNode::N45, TechNode::N7] {
        for a in [5.0, 50.0, 200.0] {
            println!(
                "{} {:>5.0} mm^2: Poisson {:.4}, Murphy {:.4}",
                node.name(),
                a,
                die_yield(node, a),
                die_yield_murphy(node, a)
            );
        }
    }

    // ---- C. 3D bandwidth contribution --------------------------------------
    println!("\n== C. 2D vs 3D delay across array sizes (vgg16@14nm) ==");
    for n in [8usize, 16, 32, 64] {
        let mk = |integration| AccelConfig {
            px: n,
            py: n,
            rf_bytes: 128,
            sram_bytes: 512 << 10,
            node: TechNode::N14,
            integration,
            mult_id: EXACT_ID,
        };
        let c2 = mk(Integration::TwoD);
        let c3 = mk(Integration::ThreeD);
        let d2 = map_network(&w, &c2).delay_s(&c2);
        let d3 = map_network(&w, &c3).delay_s(&c3);
        println!(
            "{n:>2}x{n:<2}: 2D {:7.2} ms, 3D {:7.2} ms, 3D speedup {:.2}x",
            d2 * 1e3,
            d3 * 1e3,
            d2 / d3
        );
    }

    // ---- D. FPS floor behaviour --------------------------------------------
    println!("\n== D. FPS-floor constraint handling (vgg16@7nm, δ=3%) ==");
    for target in [10.0, 20.0, 40.0, 80.0] {
        let r = ga_appx_cdp(
            &w,
            TechNode::N7,
            &lib,
            3.0,
            Some(target),
            GaParams::default(),
        );
        println!(
            "target {:>5.0} fps: got {:>6.1} fps, carbon {:>6.2} g, feasible={}",
            target, r.best_eval.fps, r.best_eval.carbon_g, r.best_eval.feasible
        );
    }
}
