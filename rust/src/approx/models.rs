//! Bit-exact behavioral models of 8x8 unsigned approximate multipliers.

use super::cost::{GateCounts, HwCost};
use super::error::ErrorMetrics;
use crate::area::TechNode;

/// Design family + parameter of an approximate 8x8 unsigned multiplier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ApproxKind {
    /// Exact 8x8 array multiplier (baseline).
    Exact,
    /// Partial-product perforation: the `p` least-significant partial-product
    /// rows of operand `b` are dropped: a * (b & !(2^p - 1)).
    Perforate(u32),
    /// Operand truncation: the `k` LSBs of *both* operands are zeroed before
    /// the exact multiply (removes AND rows and adder columns).
    Truncate(u32),
    /// Broken-array multiplier: all partial-product bits with column index
    /// (i + j) < d are dropped (the carry-save array below the d-th
    /// anti-diagonal is physically removed).
    BrokenArray(u32),
    /// Approximate compression: partial-product bits in columns < t are
    /// combined with OR instead of full adders (no carries out of the low
    /// columns). Models approximate 4:2-compressor designs.
    OrCompress(u32),
    /// Mitchell's logarithmic multiplier (piecewise-linear log/antilog).
    Mitchell,
    /// DRUM(k): dynamic-range unbiased multiplier — each operand keeps its
    /// leading k bits (LSB of the kept window forced to 1 for unbiasing),
    /// products of the reduced operands are shifted back.
    Drum(u32),
    /// Hybrid: truncate `k` LSBs of both operands, then perforate `p` rows.
    TruncPerf(u32, u32),
}

/// A library entry: behavioral model + precomputed error metrics.
#[derive(Debug, Clone)]
pub struct Multiplier {
    pub id: usize,
    pub kind: ApproxKind,
    pub error: ErrorMetrics,
    gates: GateCounts,
}

impl Multiplier {
    pub fn new(id: usize, kind: ApproxKind) -> Self {
        let gates = kind.gate_counts();
        let error = ErrorMetrics::exhaustive(&kind);
        Self { id, kind, error, gates }
    }

    /// Canonical short name (used in reports and the CLI).
    pub fn name(&self) -> String {
        match self.kind {
            ApproxKind::Exact => "EXACT".to_string(),
            ApproxKind::Perforate(p) => format!("PERF{p}"),
            ApproxKind::Truncate(k) => format!("TRUNC{k}"),
            ApproxKind::BrokenArray(d) => format!("BAM{d}"),
            ApproxKind::OrCompress(t) => format!("ORC{t}"),
            ApproxKind::Mitchell => "MITCH".to_string(),
            ApproxKind::Drum(k) => format!("DRUM{k}"),
            ApproxKind::TruncPerf(k, p) => format!("T{k}P{p}"),
        }
    }

    /// The behavioral model: approximate product of two u8 operands.
    #[inline]
    pub fn mul(&self, a: u8, b: u8) -> u32 {
        self.kind.mul(a, b)
    }

    /// Gate counts of the implementation.
    pub fn gates(&self) -> GateCounts {
        self.gates
    }

    /// Area/power/delay at a technology node.
    pub fn hw_cost(&self, node: TechNode) -> HwCost {
        self.gates.hw_cost(node)
    }
}

impl ApproxKind {
    /// Bit-exact behavioral product.
    #[inline]
    pub fn mul(&self, a: u8, b: u8) -> u32 {
        let (a, b) = (a as u32, b as u32);
        match *self {
            ApproxKind::Exact => a * b,
            ApproxKind::Perforate(p) => a * (b & !((1u32 << p) - 1)),
            ApproxKind::Truncate(k) => {
                let m = !((1u32 << k) - 1);
                (a & m) * (b & m)
            }
            ApproxKind::BrokenArray(d) => broken_array(a, b, d),
            ApproxKind::OrCompress(t) => or_compress(a, b, t),
            ApproxKind::Mitchell => mitchell(a, b),
            ApproxKind::Drum(k) => drum(a, b, k),
            ApproxKind::TruncPerf(k, p) => {
                let m = !((1u32 << k) - 1);
                (a & m) * ((b & m) & !((1u32 << p) - 1))
            }
        }
    }

    /// Gate-count structure of the design (see cost.rs for the area model).
    pub fn gate_counts(&self) -> GateCounts {
        // The exact 8x8 array: 64 partial-product AND2 gates and an adder
        // array of 8 rows; carry-save reduction uses 48 full adders + 8 half
        // adders plus a final 8-bit ripple (counted inside `adder_cells`).
        let full = GateCounts { and2: 64, fa: 48, ha: 8, aux: 16 };
        match *self {
            ApproxKind::Exact => full,
            ApproxKind::Perforate(p) => {
                // p full rows of the array vanish: 8 AND gates and ~7 adder
                // cells (FA) per row.
                GateCounts {
                    and2: full.and2 - 8 * p,
                    fa: full.fa.saturating_sub(7 * p),
                    ha: full.ha,
                    aux: full.aux,
                }
            }
            ApproxKind::Truncate(k) => {
                // k LSB columns AND rows are removed from both operands:
                // the (8-k)x(8-k) core remains.
                let n = 8 - k;
                GateCounts {
                    and2: n * n,
                    fa: (n.saturating_sub(1)) * (n.saturating_sub(2)) + n,
                    ha: n.saturating_sub(1).max(1),
                    aux: full.aux,
                }
            }
            ApproxKind::BrokenArray(d) => {
                // Cells on anti-diagonals < d are removed: d(d+1)/2 AND gates
                // and a similar count of adder cells.
                let removed = d * (d + 1) / 2;
                GateCounts {
                    and2: full.and2 - removed.min(32),
                    fa: full.fa.saturating_sub(removed.min(40)),
                    ha: full.ha,
                    aux: full.aux,
                }
            }
            ApproxKind::OrCompress(t) => {
                // Columns < t replace their adder cells with OR trees: a
                // column j < 8 has j+1 pp bits -> j OR2 gates instead of
                // ~j FAs. OR2 is ~1/5 the area of a FA.
                let freed_fa: u32 = (0..t).map(|j| j.min(7)).sum();
                GateCounts {
                    and2: full.and2,
                    fa: full.fa.saturating_sub(freed_fa),
                    ha: full.ha,
                    aux: full.aux + freed_fa / 3, // the OR trees
                }
            }
            ApproxKind::Mitchell => {
                // LOD (8) + two 3-bit encoders + 8-bit shifter x2 + 12-bit
                // adder + antilog shifter: far smaller than the array.
                GateCounts { and2: 8, fa: 14, ha: 4, aux: 52 }
            }
            ApproxKind::Drum(k) => {
                // LOD + two kxk cores + steering muxes + output shifter.
                GateCounts {
                    and2: k * k,
                    fa: (k.saturating_sub(1)) * (k.saturating_sub(2)) + k,
                    ha: k.max(1),
                    aux: 40 + 4 * k,
                }
            }
            ApproxKind::TruncPerf(k, p) => {
                let n = 8 - k;
                let t = GateCounts {
                    and2: n * n,
                    fa: (n.saturating_sub(1)) * (n.saturating_sub(2)) + n,
                    ha: n.saturating_sub(1).max(1),
                    aux: full.aux,
                };
                GateCounts {
                    and2: t.and2.saturating_sub(n * p),
                    fa: t.fa.saturating_sub((n.saturating_sub(1)) * p),
                    ha: t.ha,
                    aux: t.aux,
                }
            }
        }
    }
}

/// Broken-array: drop pp bits a_i & b_j where i + j < d.
fn broken_array(a: u32, b: u32, d: u32) -> u32 {
    let mut acc = 0u32;
    for i in 0..8 {
        if (a >> i) & 1 == 0 {
            continue;
        }
        for j in 0..8 {
            if (b >> j) & 1 == 1 && i + j >= d {
                acc += 1 << (i + j);
            }
        }
    }
    acc
}

/// OR-compress: columns < t reduce their pp bits with OR (no carries);
/// columns >= t are exact (including carries generated inside them).
fn or_compress(a: u32, b: u32, t: u32) -> u32 {
    // Exact part: products of pp bits in columns >= t.
    let mut exact = 0u32;
    let mut low_or = 0u32;
    for i in 0..8 {
        if (a >> i) & 1 == 0 {
            continue;
        }
        for j in 0..8 {
            if (b >> j) & 1 == 0 {
                continue;
            }
            let col = i + j;
            if col >= t {
                exact += 1 << col;
            } else {
                low_or |= 1 << col;
            }
        }
    }
    // The OR'd low columns produce no carries into the exact part.
    (exact & !((1u32 << t) - 1)) + low_or
}

/// Leading-one detector: index of the MSB set bit, or None for zero.
fn lod(x: u32) -> Option<u32> {
    if x == 0 {
        None
    } else {
        Some(31 - x.leading_zeros())
    }
}

/// Mitchell's logarithmic multiplier on 8-bit operands.
fn mitchell(a: u32, b: u32) -> u32 {
    let (ka, kb) = match (lod(a), lod(b)) {
        (Some(ka), Some(kb)) => (ka, kb),
        _ => return 0,
    };
    // log2(x) ~ k + frac where frac = (x - 2^k) / 2^k, kept in Q16.
    let fa = ((a - (1 << ka)) << 16) >> ka;
    let fb = ((b - (1 << kb)) << 16) >> kb;
    let ksum = ka + kb;
    let fsum = fa + fb;
    // antilog: if frac sum overflows past 1.0, bump the exponent.
    let (k, f) = if fsum >= (1 << 16) { (ksum + 1, fsum - (1 << 16)) } else { (ksum, fsum) };
    // 2^(k + f) ~ 2^k * (1 + f)
    let one_plus_f = (1u64 << 16) + f as u64; // Q16
    ((one_plus_f << k) >> 16) as u32
}

/// DRUM(k): keep the k-bit window at each operand's leading one, force the
/// window LSB to 1 (unbiasing), multiply the windows exactly, shift back.
fn drum(a: u32, b: u32, k: u32) -> u32 {
    let reduce = |x: u32| -> (u32, u32) {
        match lod(x) {
            None => (0, 0),
            Some(m) if m < k => (x, 0), // small value: exact
            Some(m) => {
                let shift = m + 1 - k;
                let win = (x >> shift) | 1; // forced LSB
                (win, shift)
            }
        }
    };
    let (wa, sa) = reduce(a);
    let (wb, sb) = reduce(b);
    (wa * wb) << (sa + sb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn exact_is_exact_exhaustively() {
        for a in 0..=255u32 {
            for b in 0..=255u32 {
                assert_eq!(ApproxKind::Exact.mul(a as u8, b as u8), a * b);
            }
        }
    }

    #[test]
    fn perforate_matches_masked_product() {
        for p in 1..=7 {
            let k = ApproxKind::Perforate(p);
            for (a, b) in [(255u32, 255u32), (128, 129), (7, 200), (0, 91)] {
                assert_eq!(k.mul(a as u8, b as u8), a * (b & !((1 << p) - 1)));
            }
        }
    }

    #[test]
    fn truncate0_equals_exact() {
        let k = ApproxKind::Truncate(0);
        for (a, b) in [(255u8, 255u8), (13, 200), (0, 0)] {
            assert_eq!(k.mul(a, b), a as u32 * b as u32);
        }
    }

    #[test]
    fn all_families_underestimate_or_equal_within_bound() {
        // Perforate/Truncate/BrokenArray/TruncPerf strictly underestimate;
        // OrCompress keeps low bits but drops carries so it also cannot
        // exceed the exact product... (OR <= sum when both nonzero).
        let kinds = [
            ApproxKind::Perforate(3),
            ApproxKind::Truncate(3),
            ApproxKind::BrokenArray(5),
            ApproxKind::OrCompress(4),
            ApproxKind::TruncPerf(2, 3),
        ];
        for kind in kinds {
            for a in (0..=255u32).step_by(3) {
                for b in (0..=255u32).step_by(7) {
                    assert!(
                        kind.mul(a as u8, b as u8) <= a * b,
                        "{kind:?} overestimates at ({a},{b})"
                    );
                }
            }
        }
    }

    #[test]
    fn mitchell_error_bound() {
        // Mitchell's multiplier has a known worst-case relative error of
        // ~11.1% (underestimation only).
        for a in 1..=255u32 {
            for b in 1..=255u32 {
                let approx = mitchell(a, b) as f64;
                let exact = (a * b) as f64;
                let rel = (exact - approx) / exact;
                assert!(
                    (-1e-9..=0.1112).contains(&rel),
                    "rel err {rel} out of Mitchell bound at ({a},{b})"
                );
            }
        }
    }

    #[test]
    fn mitchell_exact_on_powers_of_two() {
        for i in 0..8 {
            for j in 0..8 {
                let (a, b) = (1u32 << i, 1u32 << j);
                assert_eq!(mitchell(a, b), a * b, "2^{i} * 2^{j}");
            }
        }
    }

    #[test]
    fn drum_small_values_exact() {
        for k in 3..=6 {
            let d = ApproxKind::Drum(k);
            let lim = 1u32 << k;
            for a in 0..lim.min(256) {
                for b in 0..lim.min(256) {
                    assert_eq!(d.mul(a as u8, b as u8), a * b, "DRUM{k} ({a},{b})");
                }
            }
        }
    }

    #[test]
    fn drum_relative_error_shrinks_with_k_and_is_bounded() {
        // DRUM-k worst-case relative error ~ O(2^-(k-1)); assert the
        // empirical max decreases with k and stays within a loose 2x bound.
        let mut prev = f64::INFINITY;
        for k in 3..=6u32 {
            let d = ApproxKind::Drum(k);
            let mut worst = 0f64;
            for a in 1..=255u32 {
                for b in 1..=255u32 {
                    let approx = d.mul(a as u8, b as u8) as f64;
                    let exact = (a * b) as f64;
                    worst = worst.max(((approx - exact) / exact).abs());
                }
            }
            let bound = 3.0 / ((1u64 << (k - 1)) as f64);
            assert!(worst <= bound, "DRUM{k} worst {worst} > {bound}");
            assert!(worst < prev, "DRUM{k} worst {worst} !< DRUM{} {prev}", k - 1);
            prev = worst;
        }
    }

    #[test]
    fn zero_operands_give_zero_everywhere() {
        let kinds = [
            ApproxKind::Exact,
            ApproxKind::Perforate(4),
            ApproxKind::Truncate(3),
            ApproxKind::BrokenArray(6),
            ApproxKind::OrCompress(5),
            ApproxKind::Mitchell,
            ApproxKind::Drum(4),
            ApproxKind::TruncPerf(2, 2),
        ];
        for kind in kinds {
            for x in 0..=255u8 {
                assert_eq!(kind.mul(0, x), 0, "{kind:?} mul(0,{x})");
                assert_eq!(kind.mul(x, 0), 0, "{kind:?} mul({x},0)");
            }
        }
    }

    #[test]
    fn broken_array_d0_equals_exact() {
        for (a, b) in [(255u8, 255u8), (200, 13), (1, 1)] {
            assert_eq!(broken_array(a as u32, b as u32, 0), a as u32 * b as u32);
        }
    }

    #[test]
    fn or_compress_t0_equals_exact_prop() {
        prop::check("orc0-exact", 50, |rng| {
            let a = rng.below(256) as u8;
            let b = rng.below(256) as u8;
            assert_eq!(or_compress(a as u32, b as u32, 0), a as u32 * b as u32);
        });
    }

    #[test]
    fn products_fit_16_bits_prop() {
        let lib = super::super::library();
        prop::check("fits-u16", 200, |rng| {
            let m = &lib[rng.below(lib.len() as u64) as usize];
            let a = rng.below(256) as u8;
            let b = rng.below(256) as u8;
            assert!(m.mul(a, b) <= u16::MAX as u32 + 1, "{} overflow", m.name());
        });
    }

    #[test]
    fn gate_counts_shrink_with_aggressiveness() {
        let t1 = ApproxKind::Truncate(1).gate_counts().total_area_units();
        let t4 = ApproxKind::Truncate(4).gate_counts().total_area_units();
        assert!(t4 < t1);
        let p1 = ApproxKind::Perforate(1).gate_counts().total_area_units();
        let p6 = ApproxKind::Perforate(6).gate_counts().total_area_units();
        assert!(p6 < p1);
    }
}
