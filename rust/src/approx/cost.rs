//! Gate-level hardware-cost model (Synopsys-DC stand-in — DESIGN.md §6.2).
//!
//! Each multiplier reports the standard cells its structure uses; per-node
//! cell parameters (area/energy/delay of a NAND2-equivalent) convert counts
//! into um^2 / uW / ns. Cell parameters follow published std-cell-library
//! trends (45nm open-cell era -> 14nm FinFET -> 7nm FinFET); what matters for
//! the DSE is the *relative* ordering of designs within a node, which a gate
//! model preserves by construction.

use crate::area::TechNode;

/// Standard-cell composition of a multiplier implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GateCounts {
    /// Partial-product AND2 gates.
    pub and2: u32,
    /// Full adders (carry-save array + final row).
    pub fa: u32,
    /// Half adders.
    pub ha: u32,
    /// Misc cells (encoders, muxes, shifters, OR trees), NAND2-equivalents.
    pub aux: u32,
}

/// NAND2-equivalent weights per cell type (industry rules of thumb:
/// FA ~ 6 NAND2e, HA ~ 3, AND2 ~ 1.5).
const W_AND2: f64 = 1.5;
const W_FA: f64 = 6.0;
const W_HA: f64 = 3.0;
const W_AUX: f64 = 1.0;

impl GateCounts {
    /// Total NAND2-equivalent area units.
    pub fn total_area_units(&self) -> f64 {
        self.and2 as f64 * W_AND2
            + self.fa as f64 * W_FA
            + self.ha as f64 * W_HA
            + self.aux as f64 * W_AUX
    }

    /// Critical-path length estimate in FA stages: array depth shrinks as
    /// adder cells are removed (sqrt law over the reduction tree).
    pub fn critical_path_stages(&self) -> f64 {
        // Full 8x8 array: ~14 FA stages. Scale with the adder population.
        let frac = (self.fa as f64 + 0.5 * self.ha as f64) / (48.0 + 0.5 * 8.0);
        2.0 + 12.0 * frac.max(0.05).sqrt()
    }

    /// Convert to physical costs at a node.
    pub fn hw_cost(&self, node: TechNode) -> HwCost {
        let p = node.cell_params();
        let units = self.total_area_units();
        let area_um2 = units * p.nand2_area_um2;
        // Dynamic power ~ switched cap ~ area; at the node's MAC clock.
        let power_uw = units * p.nand2_dyn_pw_per_mhz * node.freq_mhz() / 1e6;
        let delay_ns = self.critical_path_stages() * p.fo4_delay_ps / 1e3;
        HwCost { area_um2, power_uw, delay_ns }
    }
}

/// Physical cost of a circuit at a technology node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HwCost {
    pub area_um2: f64,
    pub power_uw: f64,
    pub delay_ns: f64,
}

/// Per-node standard-cell parameters.
#[derive(Debug, Clone, Copy)]
pub struct CellParams {
    /// Area of a NAND2-equivalent, um^2.
    pub nand2_area_um2: f64,
    /// Dynamic power of a NAND2e in pW per MHz of toggle rate.
    pub nand2_dyn_pw_per_mhz: f64,
    /// FO4 inverter delay, ps.
    pub fo4_delay_ps: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::ApproxKind;

    #[test]
    fn exact_array_area_calibration_45nm() {
        // The exact 8x8 array at 45nm should land in the EvoApprox
        // mul8u ballpark (several hundred um^2).
        let cost = ApproxKind::Exact.gate_counts().hw_cost(TechNode::N45);
        assert!(
            (300.0..1200.0).contains(&cost.area_um2),
            "45nm exact 8x8 area {} um^2 out of ballpark",
            cost.area_um2
        );
    }

    #[test]
    fn area_shrinks_with_node() {
        let g = ApproxKind::Exact.gate_counts();
        let a45 = g.hw_cost(TechNode::N45).area_um2;
        let a14 = g.hw_cost(TechNode::N14).area_um2;
        let a7 = g.hw_cost(TechNode::N7).area_um2;
        assert!(a45 > a14 && a14 > a7);
        // 45 -> 7nm should be >10x denser.
        assert!(a45 / a7 > 10.0, "scaling {}", a45 / a7);
    }

    #[test]
    fn delay_improves_with_node() {
        let g = ApproxKind::Exact.gate_counts();
        assert!(g.hw_cost(TechNode::N45).delay_ns > g.hw_cost(TechNode::N7).delay_ns);
    }

    #[test]
    fn critical_path_shrinks_with_fewer_adders() {
        let exact = ApproxKind::Exact.gate_counts().critical_path_stages();
        let trunc = ApproxKind::Truncate(4).gate_counts().critical_path_stages();
        assert!(trunc < exact);
    }

    #[test]
    fn mitchell_is_much_smaller_than_exact() {
        let e = ApproxKind::Exact.gate_counts().total_area_units();
        let m = ApproxKind::Mitchell.gate_counts().total_area_units();
        assert!(m < 0.5 * e, "mitchell {m} vs exact {e}");
    }

    #[test]
    fn ordering_is_node_invariant() {
        // Gate model => relative ordering identical across nodes.
        let designs = [
            ApproxKind::Exact,
            ApproxKind::Truncate(2),
            ApproxKind::Perforate(4),
            ApproxKind::Mitchell,
        ];
        let order = |node: TechNode| {
            let mut ids: Vec<usize> = (0..designs.len()).collect();
            ids.sort_by(|&i, &j| {
                designs[i]
                    .gate_counts()
                    .hw_cost(node)
                    .area_um2
                    .partial_cmp(&designs[j].gate_counts().hw_cost(node).area_um2)
                    .unwrap()
            });
            ids
        };
        assert_eq!(order(TechNode::N45), order(TechNode::N14));
        assert_eq!(order(TechNode::N14), order(TechNode::N7));
    }
}
