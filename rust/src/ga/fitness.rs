//! Fitness evaluation: the paper's CDP = C_embodied x D_task, plus the
//! lifetime-carbon objectives (embodied + operational over a configurable
//! deployment) with constraint handling and a memoizing cache (the GA
//! revisits configurations constantly).

use std::collections::HashMap;
use std::sync::Arc;

use super::chromosome::Chromosome;
use crate::area::die::Integration;
use crate::area::TechNode;
use crate::carbon::operational::Deployment;
use crate::carbon::{carbon_per_mm2, embodied_carbon, CarbonBreakdown};
use crate::dataflow::arch::AccelConfig;
use crate::dataflow::cache::{CacheCounts, CacheStats, MappingCache};
use crate::dataflow::energy::EnergyModel;
use crate::dataflow::workloads::Workload;
use crate::approx::Multiplier;

/// What the search minimizes. The paper's objective is embodied CDP; the
/// lifetime objectives fold in operational energy over a deployment, which
/// lets the GA trade silicon area (embodied) against energy-per-inference
/// (operational) at each node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Embodied carbon x task delay (the paper's Carbon-Delay-Product).
    /// Carries a deployment too: fitness ignores it, but the lifetime
    /// fields of every `Evaluation` are reported under it, so an embodied
    /// campaign's rows stay comparable with a lifetime campaign's.
    EmbodiedCdp(Deployment),
    /// Lifetime *operational* carbon only (gCO2) under a deployment.
    OperationalCarbon(Deployment),
    /// (embodied + lifetime operational carbon) x task delay.
    LifetimeCdp(Deployment),
}

impl Objective {
    /// The paper's objective at the default deployment.
    pub fn embodied() -> Self {
        Objective::EmbodiedCdp(Deployment::default())
    }

    /// The deployment the objective accounts operational carbon under.
    pub fn deployment(&self) -> Deployment {
        match self {
            Objective::EmbodiedCdp(d)
            | Objective::OperationalCarbon(d)
            | Objective::LifetimeCdp(d) => *d,
        }
    }

    /// The carbon metric this objective charges a design for.
    pub fn carbon_g(&self, e: &Evaluation) -> f64 {
        match self {
            Objective::EmbodiedCdp(_) => e.carbon_g,
            Objective::OperationalCarbon(_) => e.operational_gco2,
            Objective::LifetimeCdp(_) => e.lifetime_gco2,
        }
    }

    /// The unpenalized objective value of an evaluation.
    pub fn value(&self, e: &Evaluation) -> f64 {
        match self {
            Objective::EmbodiedCdp(_) => e.cdp,
            Objective::OperationalCarbon(_) => e.operational_gco2,
            Objective::LifetimeCdp(_) => e.lifetime_cdp,
        }
    }

    /// Combine component-wise lower bounds — embodied carbon, energy per
    /// inference, task delay — into a lower bound on this objective's
    /// value. Valid because every objective is monotone non-decreasing in
    /// each component; the campaign's bound-ordered queue and prune rule
    /// are built on exactly this composition, so it lives here, beside
    /// [`Objective::value`], rather than re-deriving the objective shapes
    /// in the scheduling layer.
    pub fn lower_bound(&self, carbon_lb_g: f64, energy_lb_j: f64, delay_lb_s: f64) -> f64 {
        match self {
            Objective::EmbodiedCdp(_) => carbon_lb_g * delay_lb_s,
            Objective::OperationalCarbon(d) => d.lifetime_gco2(energy_lb_j),
            Objective::LifetimeCdp(d) => {
                (carbon_lb_g + d.lifetime_gco2(energy_lb_j)) * delay_lb_s
            }
        }
    }
}

/// Caches shared *across* fitness contexts: the geometry-keyed mapping
/// cache (DESIGN.md §7.6) and the chromosome-memo hit/miss counters. One
/// instance per campaign process (or per `dse` invocation) threads the
/// same caches through the GA population, every island thread, and every
/// campaign job, so a geometry mapped once is never mapped again —
/// whichever context asks.
#[derive(Clone, Default)]
pub struct EvalShares {
    pub mapping: Arc<MappingCache>,
    pub memo: Arc<CacheStats>,
}

/// Everything a fitness evaluation needs.
pub struct FitnessCtx<'a> {
    pub workload: &'a Workload,
    pub node: TechNode,
    pub integration: Integration,
    pub library: &'a [Multiplier],
    /// Optional FPS floor (paper §IV-B); designs below pay a penalty.
    pub fps_floor: Option<f64>,
    /// What the search minimizes (embodied CDP unless stated otherwise).
    pub objective: Objective,
    cache: HashMap<Chromosome, Evaluation>,
    /// Geometry phase memo, shareable across contexts (see [`EvalShares`]).
    mapping: Arc<MappingCache>,
    /// Chromosome-memo counters, aggregated across sharing contexts.
    memo: Arc<CacheStats>,
}

impl<'a> FitnessCtx<'a> {
    pub fn new(
        workload: &'a Workload,
        node: TechNode,
        integration: Integration,
        library: &'a [Multiplier],
        fps_floor: Option<f64>,
    ) -> Self {
        let objective = Objective::embodied();
        Self::with_objective(workload, node, integration, library, fps_floor, objective)
    }

    pub fn with_objective(
        workload: &'a Workload,
        node: TechNode,
        integration: Integration,
        library: &'a [Multiplier],
        fps_floor: Option<f64>,
        objective: Objective,
    ) -> Self {
        let shares = EvalShares::default();
        Self {
            workload,
            node,
            integration,
            library,
            fps_floor,
            objective,
            cache: HashMap::new(),
            mapping: shares.mapping,
            memo: shares.memo,
        }
    }

    /// Adopt shared caches: every context built over the same
    /// [`EvalShares`] hits one geometry-mapping cache and aggregates one
    /// set of chromosome-memo counters. Sharing never changes results —
    /// the cached mapping is the value a direct call computes.
    pub fn share(mut self, shares: &EvalShares) -> Self {
        self.mapping = shares.mapping.clone();
        self.memo = shares.memo.clone();
        self
    }

    /// Evaluate with memoization: the chromosome memo first, then the
    /// geometry/multiplier split (`evaluate_objective_cached`) on a miss.
    pub fn eval(&mut self, c: &Chromosome) -> Evaluation {
        if let Some(e) = self.cache.get(c) {
            self.memo.hit();
            crate::obs::metrics().incr("ga_memo_hits", 1);
            return *e;
        }
        self.memo.miss();
        crate::obs::metrics().incr("ga_memo_misses", 1);
        let e = evaluate_objective_cached(
            c,
            self.workload,
            self.node,
            self.integration,
            self.library,
            self.fps_floor,
            &self.objective,
            &self.mapping,
        );
        self.cache.insert(c.clone(), e);
        e
    }

    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Chromosome-memo hit/miss counters (aggregated across every context
    /// sharing this one's [`EvalShares`]).
    pub fn memo_counts(&self) -> CacheCounts {
        self.memo.counts()
    }

    /// Geometry-mapping-cache hit/miss counters.
    pub fn mapping_counts(&self) -> CacheCounts {
        self.mapping.counts()
    }

    /// Lowest-carbon *feasible* design among all evaluated configurations
    /// whose fitness is within `max_fitness`, where "carbon" is the metric
    /// the context's objective charges for (embodied for the paper's CDP,
    /// lifetime for the lifetime objectives). Used by the figure pipelines:
    /// among CDP-near-optimal designs, report the most sustainable one
    /// (CDP is flat near its optimum — carbon/delay splits there are
    /// interchangeable, and the paper reports the carbon-efficient end).
    /// Carbon ties break on the chromosome's genes, never on `HashMap`
    /// iteration order — campaign stores are compared byte-for-byte across
    /// runs, so this selection must be deterministic.
    pub fn near_optimal_min_carbon(&self, max_fitness: f64) -> Option<(Chromosome, Evaluation)> {
        let gene_key =
            |c: &Chromosome| (c.px, c.py, c.rf_bytes, c.sram_bytes, c.mult_id);
        let carbon_of = |e: &Evaluation| self.objective.carbon_g(e);
        self.cache
            .iter()
            .filter(|(_, e)| e.feasible && e.fitness <= max_fitness)
            .min_by(|a, b| {
                carbon_of(a.1)
                    .partial_cmp(&carbon_of(b.1))
                    .unwrap()
                    .then_with(|| gene_key(a.0).cmp(&gene_key(b.0)))
            })
            .map(|(c, e)| (c.clone(), *e))
    }

    /// Build the `AccelConfig` for a chromosome.
    pub fn config(&self, c: &Chromosome) -> AccelConfig {
        to_config(c, self.node, self.integration)
    }
}

/// Full evaluation of one configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Evaluation {
    /// Embodied carbon, gCO2.
    pub carbon_g: f64,
    /// Task delay, seconds.
    pub delay_s: f64,
    /// Frames per second.
    pub fps: f64,
    /// Carbon-Delay-Product (gCO2 * s).
    pub cdp: f64,
    /// Operational energy per inference, joules.
    pub energy_per_inference_j: f64,
    /// Lifetime operational carbon under the objective's deployment, gCO2.
    pub operational_gco2: f64,
    /// Lifetime total: embodied + operational, gCO2.
    pub lifetime_gco2: f64,
    /// Lifetime-Carbon-Delay-Product (gCO2 * s).
    pub lifetime_cdp: f64,
    /// Penalized fitness the GA minimizes (== the objective value when
    /// constraints hold).
    pub fitness: f64,
    /// Carbon per package mm^2 (Fig. 3 y-axis).
    pub carbon_per_mm2: f64,
    /// Total silicon, mm^2.
    pub silicon_mm2: f64,
    pub feasible: bool,
}

pub fn to_config(c: &Chromosome, node: TechNode, integration: Integration) -> AccelConfig {
    AccelConfig {
        px: c.px,
        py: c.py,
        rf_bytes: c.rf_bytes,
        sram_bytes: c.sram_bytes,
        node,
        integration,
        mult_id: c.mult_id,
    }
}

/// CDP metric (paper's objective).
pub fn cdp(carbon_g: f64, delay_s: f64) -> f64 {
    carbon_g * delay_s
}

/// Evaluate one chromosome against the paper's embodied-CDP objective.
pub fn evaluate(
    c: &Chromosome,
    workload: &Workload,
    node: TechNode,
    integration: Integration,
    library: &[Multiplier],
    fps_floor: Option<f64>,
) -> Evaluation {
    evaluate_objective(c, workload, node, integration, library, fps_floor, &Objective::embodied())
}

/// Evaluate one chromosome: carbon model (Eq. 1-5) + dataflow delay/energy
/// models + lifetime accounting under the objective's deployment, with an
/// FPS-constraint penalty if requested. Standalone form: the geometry
/// phase recomputes per call — the hot paths go through
/// [`evaluate_objective_cached`] instead.
pub fn evaluate_objective(
    c: &Chromosome,
    workload: &Workload,
    node: TechNode,
    integration: Integration,
    library: &[Multiplier],
    fps_floor: Option<f64>,
    objective: &Objective,
) -> Evaluation {
    evaluate_objective_cached(
        c,
        workload,
        node,
        integration,
        library,
        fps_floor,
        objective,
        &MappingCache::disabled(),
    )
}

/// [`evaluate_objective`] with the evaluation split by what actually
/// varies: the *geometry* phase (`map_network`, delay — a pure function of
/// `(px, py, rf, sram, node, integration, workload)`) is served by the
/// shared [`MappingCache`], while the *multiplier* phase (die areas,
/// embodied carbon, MAC energy, accuracy-constrained fitness) recomputes
/// per chromosome. Results are bit-identical to the uncached path (pinned
/// by tests here and by the CI campaign byte-identity gates).
#[allow(clippy::too_many_arguments)]
pub fn evaluate_objective_cached(
    c: &Chromosome,
    workload: &Workload,
    node: TechNode,
    integration: Integration,
    library: &[Multiplier],
    fps_floor: Option<f64>,
    objective: &Objective,
    mappings: &MappingCache,
) -> Evaluation {
    let mult = &library[c.mult_id];
    let cfg = to_config(c, node, integration);
    let areas = cfg.die_areas(mult);
    let breakdown: CarbonBreakdown = embodied_carbon(&areas, node, integration);
    let carbon_g = breakdown.total_g();
    let mapping = mappings.mapping(workload, &cfg);
    let delay_s = mapping.delay_s(&cfg);
    let fps = 1.0 / delay_s;
    let cdp_v = cdp(carbon_g, delay_s);
    let energy_j = EnergyModel::for_config(&cfg, mult).network_energy_j(&mapping);
    let operational_gco2 = objective.deployment().lifetime_gco2(energy_j);
    let lifetime_gco2 = carbon_g + operational_gco2;
    let lifetime_cdp = lifetime_gco2 * delay_s;
    let base = match objective {
        Objective::EmbodiedCdp(_) => cdp_v,
        Objective::OperationalCarbon(_) => operational_gco2,
        Objective::LifetimeCdp(_) => lifetime_cdp,
    };
    let (fitness, feasible) = match fps_floor {
        Some(floor) if fps < floor => {
            // Multiplicative penalty growing with the violation: keeps the
            // search surface smooth while making infeasible designs lose
            // every tournament against feasible ones of similar objective
            // value.
            let violation = floor / fps;
            (base * (1.0 + 10.0 * (violation - 1.0)).max(1.0) * violation, false)
        }
        _ => (base, true),
    };
    Evaluation {
        carbon_g,
        delay_s,
        fps,
        cdp: cdp_v,
        energy_per_inference_j: energy_j,
        operational_gco2,
        lifetime_gco2,
        lifetime_cdp,
        fitness,
        carbon_per_mm2: carbon_per_mm2(&breakdown, &areas),
        silicon_mm2: areas.silicon_mm2(),
        feasible,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{library, EXACT_ID};
    use crate::dataflow::workloads::workload;

    fn chrom(mult_id: usize) -> Chromosome {
        Chromosome { px: 16, py: 16, rf_bytes: 512, sram_bytes: 1 << 20, mult_id }
    }

    #[test]
    fn evaluation_fields_consistent() {
        let lib = library();
        let w = workload("resnet50").unwrap();
        let e = evaluate(&chrom(EXACT_ID), &w, TechNode::N14, Integration::ThreeD, &lib, None);
        assert!(e.carbon_g > 0.0 && e.delay_s > 0.0);
        assert!((e.cdp - e.carbon_g * e.delay_s).abs() < 1e-12);
        assert!((e.fps - 1.0 / e.delay_s).abs() < 1e-9);
        assert_eq!(e.fitness, e.cdp);
        assert!(e.feasible);
    }

    #[test]
    fn approx_multiplier_lowers_carbon_same_delay() {
        let lib = library();
        let w = workload("vgg16").unwrap();
        let exact = evaluate(&chrom(EXACT_ID), &w, TechNode::N14, Integration::ThreeD, &lib, None);
        // An aggressive truncation design (id of TRUNC4).
        let trunc = lib.iter().find(|m| m.name() == "TRUNC4").unwrap().id;
        let appr = evaluate(&chrom(trunc), &w, TechNode::N14, Integration::ThreeD, &lib, None);
        assert!(appr.carbon_g < exact.carbon_g);
        assert_eq!(appr.delay_s, exact.delay_s); // same array dims -> same delay
        assert!(appr.cdp < exact.cdp);
    }

    #[test]
    fn fps_penalty_applies_only_below_floor() {
        let lib = library();
        let w = workload("vgg16").unwrap();
        let free = evaluate(&chrom(EXACT_ID), &w, TechNode::N14, Integration::ThreeD, &lib, None);
        let hard_floor = free.fps * 4.0;
        let pen = evaluate(
            &chrom(EXACT_ID),
            &w,
            TechNode::N14,
            Integration::ThreeD,
            &lib,
            Some(hard_floor),
        );
        assert!(!pen.feasible);
        assert!(pen.fitness > pen.cdp);
        let easy = evaluate(
            &chrom(EXACT_ID),
            &w,
            TechNode::N14,
            Integration::ThreeD,
            &lib,
            Some(free.fps * 0.5),
        );
        assert!(easy.feasible);
        assert_eq!(easy.fitness, easy.cdp);
    }

    #[test]
    fn objective_values_are_internally_consistent() {
        let lib = library();
        let w = workload("resnet50").unwrap();
        let dep = crate::carbon::operational::Deployment {
            inferences_per_day: 1_000_000.0,
            ..Default::default()
        };
        let c = chrom(EXACT_ID);
        let emb = evaluate(&c, &w, TechNode::N14, Integration::ThreeD, &lib, None);
        let op = evaluate_objective(
            &c,
            &w,
            TechNode::N14,
            Integration::ThreeD,
            &lib,
            None,
            &Objective::OperationalCarbon(dep),
        );
        let life = evaluate_objective(
            &c,
            &w,
            TechNode::N14,
            Integration::ThreeD,
            &lib,
            None,
            &Objective::LifetimeCdp(dep),
        );
        // Same design, same physics: embodied/delay/energy identical.
        assert_eq!(emb.carbon_g, op.carbon_g);
        assert_eq!(emb.delay_s, life.delay_s);
        assert_eq!(emb.energy_per_inference_j, life.energy_per_inference_j);
        assert!(emb.energy_per_inference_j > 0.0);
        // Fitness tracks the declared objective.
        assert_eq!(op.fitness, op.operational_gco2);
        assert_eq!(life.fitness, life.lifetime_cdp);
        assert!((life.lifetime_gco2 - (life.carbon_g + life.operational_gco2)).abs() < 1e-9);
        assert!((life.lifetime_cdp - life.lifetime_gco2 * life.delay_s).abs() < 1e-9);
        // Lifetime carbon strictly exceeds embodied (operational > 0), so
        // lifetime CDP strictly exceeds embodied CDP at the same design.
        assert!(life.lifetime_gco2 > life.carbon_g);
        assert!(life.lifetime_cdp > life.cdp);
        // Heavier duty -> more operational carbon at the same design.
        assert!(op.operational_gco2 > emb.operational_gco2);
    }

    #[test]
    fn objective_helpers_pick_the_right_metric() {
        let lib = library();
        let w = workload("vgg16").unwrap();
        let e = evaluate(&chrom(EXACT_ID), &w, TechNode::N7, Integration::ThreeD, &lib, None);
        let dep = crate::carbon::operational::Deployment::default();
        assert_eq!(Objective::embodied().carbon_g(&e), e.carbon_g);
        assert_eq!(Objective::OperationalCarbon(dep).carbon_g(&e), e.operational_gco2);
        assert_eq!(Objective::LifetimeCdp(dep).carbon_g(&e), e.lifetime_gco2);
        assert_eq!(Objective::embodied().value(&e), e.cdp);
        assert_eq!(Objective::OperationalCarbon(dep).value(&e), e.operational_gco2);
        assert_eq!(Objective::LifetimeCdp(dep).value(&e), e.lifetime_cdp);
    }

    #[test]
    fn lower_bound_composes_exactly_like_value() {
        // Feeding an evaluation's own components through `lower_bound`
        // must reproduce `value` for every objective: the bound is the
        // same composition applied to per-component minima.
        let lib = library();
        let w = workload("resnet50").unwrap();
        let dep = crate::carbon::operational::Deployment {
            inferences_per_day: 500_000.0,
            ..Default::default()
        };
        let e = evaluate_objective(
            &chrom(EXACT_ID),
            &w,
            TechNode::N14,
            Integration::ThreeD,
            &lib,
            None,
            &Objective::LifetimeCdp(dep),
        );
        for obj in [
            Objective::EmbodiedCdp(dep),
            Objective::OperationalCarbon(dep),
            Objective::LifetimeCdp(dep),
        ] {
            let composed = obj.lower_bound(e.carbon_g, e.energy_per_inference_j, e.delay_s);
            assert!(
                (composed - obj.value(&e)).abs() <= 1e-9 * obj.value(&e).abs(),
                "{obj:?}: {composed} vs {}",
                obj.value(&e)
            );
        }
    }

    #[test]
    fn lifetime_objective_rewards_energy_efficiency() {
        // Under a heavy-duty deployment the operational term dominates, so
        // an approximate multiplier (cheaper MACs) must strictly lower the
        // lifetime objective at an otherwise identical design.
        let lib = library();
        let w = workload("vgg16").unwrap();
        let dep = crate::carbon::operational::Deployment {
            inferences_per_day: 10_000_000.0,
            ..Default::default()
        };
        let obj = Objective::LifetimeCdp(dep);
        let trunc = lib.iter().find(|m| m.name() == "TRUNC4").unwrap().id;
        let exact = evaluate_objective(
            &chrom(EXACT_ID),
            &w,
            TechNode::N14,
            Integration::ThreeD,
            &lib,
            None,
            &obj,
        );
        let appr = evaluate_objective(
            &chrom(trunc),
            &w,
            TechNode::N14,
            Integration::ThreeD,
            &lib,
            None,
            &obj,
        );
        assert!(appr.energy_per_inference_j < exact.energy_per_inference_j);
        assert!(appr.fitness < exact.fitness);
    }

    #[test]
    fn cache_hits_return_identical_results() {
        let lib = library();
        let w = workload("densenet121").unwrap();
        let mut ctx = FitnessCtx::new(&w, TechNode::N7, Integration::ThreeD, &lib, None);
        let c = chrom(EXACT_ID);
        let a = ctx.eval(&c);
        let n = ctx.cache_len();
        let b = ctx.eval(&c);
        assert_eq!(a, b);
        assert_eq!(ctx.cache_len(), n);
        let memo = ctx.memo_counts();
        assert_eq!((memo.hits, memo.misses), (1, 1));
    }

    #[test]
    fn cached_eval_bit_identical_to_uncached_across_multipliers() {
        // The byte-identity oracle for the geometry/multiplier split: for
        // designs differing only in the multiplier gene, the shared-cache
        // path must reproduce the standalone evaluation bit-for-bit, while
        // charging the mapper exactly once for the shared geometry.
        let lib = library();
        let w = workload("vgg16").unwrap();
        let shares = EvalShares::default();
        let mut ctx = FitnessCtx::new(&w, TechNode::N14, Integration::ThreeD, &lib, Some(20.0))
            .share(&shares);
        let mult_ids = [EXACT_ID, 3, 9, 17, 26, lib.len() - 1];
        for &mult_id in &mult_ids {
            let c = chrom(mult_id);
            let cached = ctx.eval(&c);
            let plain =
                evaluate(&c, &w, TechNode::N14, Integration::ThreeD, &lib, Some(20.0));
            assert_eq!(cached.carbon_g.to_bits(), plain.carbon_g.to_bits(), "mult {mult_id}");
            assert_eq!(cached.delay_s.to_bits(), plain.delay_s.to_bits(), "mult {mult_id}");
            assert_eq!(
                cached.energy_per_inference_j.to_bits(),
                plain.energy_per_inference_j.to_bits(),
                "mult {mult_id}"
            );
            assert_eq!(cached.fitness.to_bits(), plain.fitness.to_bits(), "mult {mult_id}");
            assert_eq!(cached, plain, "mult {mult_id}");
        }
        // One geometry, many multipliers: exactly one mapper run.
        let mc = shares.mapping.counts();
        assert_eq!((mc.misses, mc.hits), (1, mult_ids.len() - 1));
        assert_eq!(shares.mapping.len(), 1);
    }

    #[test]
    fn shared_contexts_aggregate_counters() {
        let lib = library();
        let w = workload("resnet50").unwrap();
        let shares = EvalShares::default();
        let mut a = FitnessCtx::new(&w, TechNode::N14, Integration::ThreeD, &lib, None)
            .share(&shares);
        let mut b = FitnessCtx::new(&w, TechNode::N14, Integration::ThreeD, &lib, None)
            .share(&shares);
        let c = chrom(EXACT_ID);
        assert_eq!(a.eval(&c), b.eval(&c));
        // Context b's geometry lookup hits the mapping a populated, even
        // though its own chromosome memo missed.
        let mc = shares.mapping.counts();
        assert_eq!((mc.misses, mc.hits), (1, 1));
        let memo = shares.memo.counts();
        assert_eq!((memo.hits, memo.misses), (0, 2));
    }
}
