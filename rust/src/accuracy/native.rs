//! Native bit-faithful evaluator: the trained tiny CNN through the
//! approximate bf16 MAC datapath, entirely in Rust.
//!
//! Semantics mirror python/compile/kernels/ref.py exactly:
//!   bf16 RNE rounding -> sign/exp/mant decompose -> LUT significand product
//!   -> exact power-of-two scale -> f32 accumulation; zeros/denormals flush.
//! Layer plumbing mirrors python/compile/model.py (im2col patch order
//! (dy,dx,c), 'same' padding, maxpool2, fc).

use std::path::Path;
use std::sync::OnceLock;

use anyhow::{ensure, Context, Result};

use crate::approx::Multiplier;
use crate::runtime::artifacts::Artifacts;

/// bf16 round-to-nearest-even, result as f32 with low 16 bits zero.
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    let lsb = (bits >> 16) & 1;
    f32::from_bits(bits.wrapping_add(0x7FFF + lsb) & 0xFFFF_0000)
}

/// Exact f32 2^e for integer e (3-factor clamped chain; matches
/// ref.pow2_exact).
#[inline]
fn pow2_exact(e: i32) -> f32 {
    let factor = |ei: i32| f32::from_bits(((ei + 127) as u32) << 23);
    let e1 = e.clamp(-126, 127);
    let r = e - e1;
    let e2 = r.clamp(-126, 127);
    let e3 = (r - e2).clamp(-126, 127);
    factor(e1) * factor(e2) * factor(e3)
}

/// The shared 512-entry exponent-scale table: entry `s` — the sum of two
/// biased bf16 exponents, so 2..=510 for non-flushed operands — holds
/// `pow2_exact(s - 268)`, replacing the per-product `pow2_exact` chain of
/// the scalar path with one load. Process-global: the table depends on
/// nothing but IEEE-754, so every datapath (and the eval service's
/// backends) shares one copy.
fn scale_table() -> &'static [f32] {
    static SCALE: OnceLock<Vec<f32>> = OnceLock::new();
    SCALE.get_or_init(|| (0..512i32).map(|s| pow2_exact(s - 268)).collect())
}

/// Worker threads for row-chunked matmuls: `CARBON3D_MATMUL_THREADS` if
/// set (0/unparsable ignored), else the machine's available parallelism.
/// Thread count never changes results — rows are independent and per-row
/// accumulation order is fixed — so this is purely a throughput knob.
fn matmul_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        std::env::var("CARBON3D_MATMUL_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&t| t > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Lane width of the explicit-width row kernel. Eight f32 lanes fill one
/// AVX2 register (or two NEON quads); the kernel is written as
/// fixed-length `[f32; LANES]` loops with no cross-lane dependencies, so
/// LLVM lowers the multiply and the masked accumulate to vector ops on
/// stable Rust without `std::simd`.
const LANES: usize = 8;

/// Whether the lane kernel is the default row kernel: on unless
/// `CARBON3D_SIMD` is `0`/`off`/`false`. Cached once per process (like
/// [`matmul_threads`]); both kernels are always compiled and bit-identical
/// to [`ApproxDatapath::matmul_reference`], so this is purely a throughput
/// knob — tests and benches pin a specific kernel via [`MatmulKernel`]
/// instead of the environment.
fn simd_enabled() -> bool {
    static SIMD: OnceLock<bool> = OnceLock::new();
    *SIMD.get_or_init(|| {
        !matches!(
            std::env::var("CARBON3D_SIMD").ok().as_deref(),
            Some("0") | Some("off") | Some("false")
        )
    })
}

/// Row-kernel selection for the table-driven matmul (DESIGN.md §9.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatmulKernel {
    /// The runtime default: [`MatmulKernel::Lanes`] unless `CARBON3D_SIMD`
    /// disables it.
    Auto,
    /// Force the explicit-width lane kernel (identity-padded tail).
    Lanes,
    /// Force the scalar row kernel — the always-compiled fallback.
    Scalar,
}

impl MatmulKernel {
    /// Resolve `Auto` against the process environment.
    fn lanes(self) -> bool {
        match self {
            MatmulKernel::Auto => simd_enabled(),
            MatmulKernel::Lanes => true,
            MatmulKernel::Scalar => false,
        }
    }
}

/// The inline-vs-threaded heuristic shared by every auto-threaded entry
/// point: small problems (the tiny CNN's fc layer, unit-test shapes) don't
/// amortize scoped-thread spawn/join, so they run inline.
fn auto_threads(m: usize, k: usize, n: usize) -> usize {
    const PARALLEL_MIN_PRODUCTS: usize = 1 << 20;
    if m * k * n < PARALLEL_MIN_PRODUCTS {
        1
    } else {
        matmul_threads()
    }
}

/// Decode one operand for the table-driven path: pack `mant<<1 | signbit`
/// (the sign-folded-LUT index half) and keep the biased exponent
/// separately; exp == 0 marks zero/denormal (flushed).
#[inline]
fn decode(x: f32) -> (u32, i32) {
    let bits = bf16_round(x).to_bits();
    let exp = ((bits >> 23) & 0xFF) as i32;
    let key = ((bits >> 16) & 0x7F) << 1 | (bits >> 31);
    (key, exp)
}

/// The approximate MAC datapath for one multiplier LUT.
pub struct ApproxDatapath {
    /// 128x128 significand products (u16 range), f32 for parity with the
    /// AOT kernel input. Retained for `mul` / `matmul_reference`.
    lut: Vec<f32>,
    /// 256x256 sign-folded LUT: entry `(ma<<1|sa, mb<<1|sb)` holds
    /// `±lut[ma][mb]` with the product sign folded in, replacing the
    /// per-product XOR branch with a straight load. Bit-exact because
    /// IEEE-754 multiplication makes `(-sig)*scale == -(sig*scale)`.
    slut: Vec<f32>,
}

impl ApproxDatapath {
    pub fn new(mult: &Multiplier) -> Self {
        Self::from_lut(crate::approx::lut_f32(mult))
    }

    pub fn from_lut(lut: Vec<f32>) -> Self {
        assert_eq!(lut.len(), 128 * 128);
        let mut slut = vec![0f32; 256 * 256];
        for ma in 0..128usize {
            for mb in 0..128usize {
                let sig = lut[ma * 128 + mb];
                for sa in 0..2usize {
                    for sb in 0..2usize {
                        let v = if sa != sb { -sig } else { sig };
                        slut[((ma << 1) | sa) * 256 + ((mb << 1) | sb)] = v;
                    }
                }
            }
        }
        Self { lut, slut }
    }

    /// One approximate product (ref.approx_mul_elementwise semantics).
    #[inline]
    pub fn mul(&self, a: f32, b: f32) -> f32 {
        let ab = bf16_round(a).to_bits();
        let bb = bf16_round(b).to_bits();
        let ea = (ab >> 23) & 0xFF;
        let eb = (bb >> 23) & 0xFF;
        if ea == 0 || eb == 0 {
            return 0.0;
        }
        let ma = (ab >> 16) & 0x7F;
        let mb = (bb >> 16) & 0x7F;
        let sig = self.lut[(ma * 128 + mb) as usize];
        let scale = pow2_exact(ea as i32 + eb as i32 - 268);
        let sign = if (ab ^ bb) & 0x8000_0000 != 0 { -1.0f32 } else { 1.0f32 };
        sign * (sig * scale)
    }

    /// [M,K] x [K,N] matmul with f32 accumulation over ascending k.
    ///
    /// Hot path of the native evaluator, table-driven (DESIGN.md §7.6):
    /// operands are decomposed to (sign|mant, exp) *once* up front; each
    /// product is then two loads and a fused sign (the 256x256 sign-folded
    /// LUT) times a scale lookup (the shared 512-entry exponent table),
    /// and rows of M are chunked across std threads. Per-row accumulation
    /// order is unchanged, so results are bit-identical to
    /// [`ApproxDatapath::matmul_reference`] for every thread count.
    pub fn matmul(&self, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        self.matmul_with_threads(a, b, m, k, n, auto_threads(m, k, n))
    }

    /// [`ApproxDatapath::matmul`] with an explicit worker count (the
    /// property tests sweep this to pin thread-count independence).
    pub fn matmul_with_threads(
        &self,
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        threads: usize,
    ) -> Vec<f32> {
        self.matmul_with_kernel(a, b, m, k, n, threads, MatmulKernel::Auto)
    }

    /// [`ApproxDatapath::matmul`] with an explicit worker count *and* row
    /// kernel — the form the bit-identity property tests and
    /// `benches/native.rs` use to pin both datapaths regardless of the
    /// process environment.
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_with_kernel(
        &self,
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        threads: usize,
        kernel: MatmulKernel,
    ) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        self.matmul_into(a, b, &mut out, m, k, n, threads, kernel);
        out
    }

    /// The batched entry point: compute `[M,K] x [K,N]` into a
    /// caller-owned buffer (`out.len() == m * n`), allocating nothing but
    /// the decode scratch. [`NativeEvaluator::forward_into`] drives whole
    /// image batches through this with a preallocated [`BatchBuffers`]
    /// pool, so an accuracy pass performs one set of allocations total.
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_into(
        &self,
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        threads: usize,
        kernel: MatmulKernel,
    ) {
        let _span = crate::obs::span("native.matmul");
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(out.len(), m * n);
        out.fill(0.0);
        if m == 0 || k == 0 || n == 0 {
            return; // no products: all-zero output, as the loops produce
        }
        let lanes = kernel.lanes();
        let da: Vec<(u32, i32)> = a.iter().map(|&x| decode(x)).collect();
        // The lane kernel reads B rows padded to a LANES multiple; the
        // identity element (key 0, exp 0) is flushed by the accumulate
        // mask, so tail lanes never touch the result. When n is already a
        // multiple (or the scalar kernel runs), the plain decode IS the
        // padded layout.
        let np = if lanes { n.div_ceil(LANES) * LANES } else { n };
        let db: Vec<(u32, i32)> = if np == n {
            b.iter().map(|&x| decode(x)).collect()
        } else {
            let mut padded = vec![(0u32, 0i32); k * np];
            for (row, b_row) in padded.chunks_mut(np).zip(b.chunks(n)) {
                for (d, &x) in row.iter_mut().zip(b_row) {
                    *d = decode(x);
                }
            }
            padded
        };
        let threads = threads.clamp(1, m);
        if threads == 1 {
            self.matmul_chunk(lanes, &da, &db, out, k, n, np);
            return;
        }
        let rows_per = m.div_ceil(threads);
        std::thread::scope(|scope| {
            for (a_rows, out_rows) in
                da.chunks(rows_per * k).zip(out.chunks_mut(rows_per * n))
            {
                let db = &db;
                scope.spawn(move || {
                    self.matmul_chunk(lanes, a_rows, db, out_rows, k, n, np)
                });
            }
        });
    }

    /// One worker's share of the matmul: dispatch the selected row kernel
    /// over a matching (`a_rows`, `out_rows`) chunk pair.
    #[allow(clippy::too_many_arguments)]
    fn matmul_chunk(
        &self,
        lanes: bool,
        a_rows: &[(u32, i32)],
        db: &[(u32, i32)],
        out_rows: &mut [f32],
        k: usize,
        n: usize,
        np: usize,
    ) {
        let _chunk = crate::obs::span("native.matmul_chunk");
        if lanes {
            self.matmul_rows_lanes(a_rows, db, out_rows, k, n, np);
        } else {
            self.matmul_rows(a_rows, db, out_rows, k, n);
        }
    }

    /// The scalar table-driven row kernel — the always-compiled fallback:
    /// `a_rows` and `out_rows` are matching row chunks of the
    /// operand/output matrices.
    fn matmul_rows(
        &self,
        a_rows: &[(u32, i32)],
        db: &[(u32, i32)],
        out_rows: &mut [f32],
        k: usize,
        n: usize,
    ) {
        let scale = scale_table();
        for (a_row, out_row) in a_rows.chunks(k).zip(out_rows.chunks_mut(n)) {
            for (kk, &(ka, ea)) in a_row.iter().enumerate() {
                if ea == 0 {
                    continue;
                }
                let base = (ka as usize) << 8;
                let srow = &self.slut[base..base + 256];
                let b_row = &db[kk * n..(kk + 1) * n];
                for (o, &(kb, eb)) in out_row.iter_mut().zip(b_row) {
                    if eb == 0 {
                        continue;
                    }
                    *o += srow[kb as usize] * scale[(ea + eb) as usize];
                }
            }
        }
    }

    /// The explicit-width lane row kernel (DESIGN.md §9.1): B rows arrive
    /// padded to `np` (a LANES multiple) with the identity element, each
    /// LANES-wide group performs the two table loads and the multiply for
    /// all lanes, and a *masked select* folds the products into a padded
    /// per-row accumulator. The mask must select, never add `+0.0`: slut
    /// entries can be `-0.0`, and `-0.0 + 0.0 == +0.0` would flip the
    /// accumulator's sign bit where the scalar kernel's `continue` leaves
    /// it untouched. Ascending-k order is unchanged, so every lane matches
    /// [`ApproxDatapath::matmul_reference`] bit for bit.
    #[allow(clippy::needless_range_loop)]
    fn matmul_rows_lanes(
        &self,
        a_rows: &[(u32, i32)],
        db: &[(u32, i32)],
        out_rows: &mut [f32],
        k: usize,
        n: usize,
        np: usize,
    ) {
        debug_assert_eq!(np % LANES, 0);
        let scale = scale_table();
        let mut acc = vec![0f32; np];
        for (a_row, out_row) in a_rows.chunks(k).zip(out_rows.chunks_mut(n)) {
            acc.fill(0.0);
            for (kk, &(ka, ea)) in a_row.iter().enumerate() {
                if ea == 0 {
                    continue;
                }
                let base = (ka as usize) << 8;
                let srow = &self.slut[base..base + 256];
                let b_row = &db[kk * np..(kk + 1) * np];
                for (acc_l, b_l) in
                    acc.chunks_exact_mut(LANES).zip(b_row.chunks_exact(LANES))
                {
                    let mut prod = [0f32; LANES];
                    for l in 0..LANES {
                        let (kb, eb) = b_l[l];
                        // Padding/flushed lanes load srow[0] * scale[ea]:
                        // finite garbage the mask below discards.
                        prod[l] = srow[kb as usize] * scale[(ea + eb) as usize];
                    }
                    for l in 0..LANES {
                        acc_l[l] =
                            if b_l[l].1 != 0 { acc_l[l] + prod[l] } else { acc_l[l] };
                    }
                }
            }
            out_row.copy_from_slice(&acc[..n]);
        }
    }

    /// The retained scalar reference: one `mul` per product with the same
    /// ascending-k accumulation order. Slow by design — the bit-identity
    /// property tests and `benches/native.rs` measure the table-driven
    /// path against this loop.
    pub fn matmul_reference(&self, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let av = a[i * k + kk];
                let out_row = &mut out[i * n..(i + 1) * n];
                for (o, &bv) in out_row.iter_mut().zip(&b[kk * n..(kk + 1) * n]) {
                    *o += self.mul(av, bv);
                }
            }
        }
        out
    }
}

/// Trained tiny-CNN weights (PARAM_SPECS order, see python/compile/model.py).
#[derive(Debug, Clone)]
pub struct Weights {
    pub conv1_w: Vec<f32>, // [3,3,1,8]
    pub conv1_b: Vec<f32>, // [8]
    pub conv2_w: Vec<f32>, // [3,3,8,16]
    pub conv2_b: Vec<f32>, // [16]
    pub fc_w: Vec<f32>,    // [256,5]
    pub fc_b: Vec<f32>,    // [5]
}

/// Test-set images + labels.
#[derive(Debug, Clone)]
pub struct TestSet {
    pub images: Vec<f32>, // [n,16,16,1]
    pub labels: Vec<u8>,
    pub n: usize,
}

/// The native evaluator: weights + test set + forward pass.
pub struct NativeEvaluator {
    pub weights: Weights,
    pub testset: TestSet,
    pub exact_accuracy: f64,
}

pub const IMG: usize = 16;
pub const NUM_CLASSES: usize = 5;

impl NativeEvaluator {
    /// Load from the artifacts directory (weights.f32, testset_*, manifest).
    pub fn load(artifacts: &Artifacts) -> Result<Self> {
        let dir = &artifacts.dir;
        let w = read_f32(&dir.join("weights.f32"))?;
        let sizes = [3 * 3 * 8, 8, 3 * 3 * 8 * 16, 16, 256 * 5, 5];
        ensure!(
            w.len() == sizes.iter().sum::<usize>(),
            "weights.f32 has {} floats, want {}",
            w.len(),
            sizes.iter().sum::<usize>()
        );
        let mut off = 0;
        let mut take = |n: usize| {
            let v = w[off..off + n].to_vec();
            off += n;
            v
        };
        let weights = Weights {
            conv1_w: take(sizes[0]),
            conv1_b: take(sizes[1]),
            conv2_w: take(sizes[2]),
            conv2_b: take(sizes[3]),
            fc_w: take(sizes[4]),
            fc_b: take(sizes[5]),
        };
        let images = read_f32(&dir.join("testset_images.f32"))?;
        let labels = std::fs::read(dir.join("testset_labels.u8"))
            .context("read testset_labels.u8")?;
        let n = labels.len();
        ensure!(images.len() == n * IMG * IMG, "testset images/labels mismatch");
        Ok(Self {
            weights,
            testset: TestSet { images, labels, n },
            exact_accuracy: artifacts.exact_test_accuracy,
        })
    }

    /// Forward pass for a batch of images through the approximate datapath.
    /// `images` is [b,16,16,1] row-major. Returns logits [b,NUM_CLASSES].
    /// Convenience wrapper over [`NativeEvaluator::forward_into`] that
    /// allocates a one-shot [`BatchBuffers`] pool.
    pub fn forward(&self, dp: &ApproxDatapath, images: &[f32], b: usize) -> Vec<f32> {
        let mut buf = BatchBuffers::new(b.max(1));
        self.forward_into(dp, images, b, &mut buf).to_vec()
    }

    /// The batched forward pass: push one image batch through the network
    /// using `buf`'s preallocated im2col and intermediate buffers, and
    /// return the logits slice `[b, NUM_CLASSES]` borrowed from the pool.
    /// Results are bit-identical for every batch split — image rows are
    /// independent matmul rows — which the batching property test pins.
    pub fn forward_into<'a>(
        &self,
        dp: &ApproxDatapath,
        images: &[f32],
        b: usize,
        buf: &'a mut BatchBuffers,
    ) -> &'a [f32] {
        assert!(b <= buf.max_b, "batch {b} exceeds buffer capacity {}", buf.max_b);
        assert_eq!(images.len(), b * IMG * IMG);
        let w = &self.weights;
        // conv1: 16x16x1 -> 16x16x8, relu, pool -> 8x8x8
        let c1 = &mut buf.c1[..b * IMG * IMG * 8];
        conv2d_same_into(
            dp,
            images,
            b,
            IMG,
            IMG,
            1,
            &w.conv1_w,
            &w.conv1_b,
            8,
            &mut buf.cols1[..b * IMG * IMG * 9],
            c1,
        );
        relu_in_place(c1);
        maxpool2_into(c1, b, IMG, IMG, 8, &mut buf.p1[..b * 8 * 8 * 8]);
        // conv2: 8x8x8 -> 8x8x16, relu, pool -> 4x4x16
        let c2 = &mut buf.c2[..b * 8 * 8 * 16];
        conv2d_same_into(
            dp,
            &buf.p1[..b * 8 * 8 * 8],
            b,
            8,
            8,
            8,
            &w.conv2_w,
            &w.conv2_b,
            16,
            &mut buf.cols2[..b * 8 * 8 * 72],
            c2,
        );
        relu_in_place(c2);
        maxpool2_into(c2, b, 8, 8, 16, &mut buf.p2[..b * 256]);
        // fc: 256 -> 5
        let logits = &mut buf.logits[..b * NUM_CLASSES];
        dp.matmul_into(
            &buf.p2[..b * 256],
            &w.fc_w,
            logits,
            b,
            256,
            NUM_CLASSES,
            auto_threads(b, 256, NUM_CLASSES),
            MatmulKernel::Auto,
        );
        for row in logits.chunks_mut(NUM_CLASSES) {
            for (x, bias) in row.iter_mut().zip(&w.fc_b) {
                *x += bias;
            }
        }
        &buf.logits[..b * NUM_CLASSES]
    }

    /// Top-1 accuracy of a multiplier datapath over the whole test set,
    /// batched at 64 images (small enough to keep im2col buffers cachey,
    /// large enough to amortize the per-call decode).
    pub fn accuracy(&self, dp: &ApproxDatapath) -> f64 {
        self.accuracy_batched(dp, 64)
    }

    /// [`NativeEvaluator::accuracy`] with an explicit batch size: one
    /// [`BatchBuffers`] pool is allocated up front and every batch flows
    /// through a single [`NativeEvaluator::forward_into`] call. Accuracy
    /// is identical for every batch size (pinned by test).
    pub fn accuracy_batched(&self, dp: &ApproxDatapath, batch: usize) -> f64 {
        let n = self.testset.n;
        if n == 0 {
            return 0.0;
        }
        let bs = batch.clamp(1, n);
        let mut buf = BatchBuffers::new(bs);
        let mut correct = 0usize;
        for start in (0..n).step_by(bs) {
            let b = bs.min(n - start);
            let imgs = &self.testset.images[start * IMG * IMG..(start + b) * IMG * IMG];
            let logits = self.forward_into(dp, imgs, b, &mut buf);
            for i in 0..b {
                let row = &logits[i * NUM_CLASSES..(i + 1) * NUM_CLASSES];
                if argmax(row) == self.testset.labels[start + i] as usize {
                    correct += 1;
                }
            }
        }
        correct as f64 / n as f64
    }
}

/// Preallocated scratch for [`NativeEvaluator::forward_into`]: the im2col
/// patch buffers, the conv/pool intermediates, and the logits for a batch
/// of up to `max_b` images, so an accuracy pass allocates once instead of
/// seven times per batch. Contents are overwritten in full by each
/// forward pass — reuse can never leak one batch into the next.
pub struct BatchBuffers {
    max_b: usize,
    cols1: Vec<f32>,
    c1: Vec<f32>,
    p1: Vec<f32>,
    cols2: Vec<f32>,
    c2: Vec<f32>,
    p2: Vec<f32>,
    logits: Vec<f32>,
}

impl BatchBuffers {
    /// Size every buffer for batches of up to `max_b` images.
    pub fn new(max_b: usize) -> Self {
        Self {
            max_b,
            cols1: vec![0f32; max_b * IMG * IMG * 9],
            c1: vec![0f32; max_b * IMG * IMG * 8],
            p1: vec![0f32; max_b * 8 * 8 * 8],
            cols2: vec![0f32; max_b * 8 * 8 * 72],
            c2: vec![0f32; max_b * 8 * 8 * 16],
            p2: vec![0f32; max_b * 256],
            logits: vec![0f32; max_b * NUM_CLASSES],
        }
    }

    /// The largest batch this pool can carry.
    pub fn capacity(&self) -> usize {
        self.max_b
    }
}

/// Deterministic, NaN-safe top-1 argmax: the *first* index holding the
/// maximum non-NaN value. NaN logits never win (a NaN incumbent is
/// replaced by the first non-NaN candidate; `>` against NaN is false
/// otherwise), and an all-NaN row deterministically yields 0 — where the
/// old `partial_cmp(..).unwrap()` argmax panicked the whole evaluation.
/// Aggressive approximate multipliers can overflow logits to ±inf and
/// breed NaNs downstream, so this is reachable from real LUTs, not just
/// adversarial inputs.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate().skip(1) {
        if (row[best].is_nan() && !v.is_nan()) || v > row[best] {
            best = i;
        }
    }
    best
}

fn relu_in_place(v: &mut [f32]) {
    for x in v {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
}

/// 'same' 3x3 conv via im2col + approx matmul; patch order (dy,dx,c) matches
/// model.im2col. Allocating wrapper over [`conv2d_same_into`] (tests).
#[cfg(test)]
#[allow(clippy::too_many_arguments)]
fn conv2d_same(
    dp: &ApproxDatapath,
    x: &[f32],
    b: usize,
    h: usize,
    wd: usize,
    cin: usize,
    weights: &[f32], // [3,3,cin,cout]
    bias: &[f32],
    cout: usize,
) -> Vec<f32> {
    let mut cols = vec![0f32; b * h * wd * 9 * cin];
    let mut out = vec![0f32; b * h * wd * cout];
    conv2d_same_into(dp, x, b, h, wd, cin, weights, bias, cout, &mut cols, &mut out);
    out
}

/// 'same' 3x3 conv into caller-owned buffers: `cols` is the im2col scratch
/// (`b*h*wd*9*cin`, every cell written), `out` receives `[b*h*wd, cout]`.
/// Patch order (dy,dx,c) matches model.im2col.
#[allow(clippy::too_many_arguments)]
fn conv2d_same_into(
    dp: &ApproxDatapath,
    x: &[f32],
    b: usize,
    h: usize,
    wd: usize,
    cin: usize,
    weights: &[f32], // [3,3,cin,cout]
    bias: &[f32],
    cout: usize,
    cols: &mut [f32],
    out: &mut [f32],
) {
    let k = 3usize;
    let pad = 1usize;
    let patch = k * k * cin;
    assert_eq!(cols.len(), b * h * wd * patch);
    for bi in 0..b {
        for y in 0..h {
            for xx in 0..wd {
                let row = ((bi * h + y) * wd + xx) * patch;
                let mut p = 0usize;
                for dy in 0..k {
                    for dx in 0..k {
                        let sy = y as isize + dy as isize - pad as isize;
                        let sx = xx as isize + dx as isize - pad as isize;
                        for c in 0..cin {
                            cols[row + p] = if sy >= 0
                                && sy < h as isize
                                && sx >= 0
                                && sx < wd as isize
                            {
                                x[((bi * h + sy as usize) * wd + sx as usize) * cin + c]
                            } else {
                                0.0
                            };
                            p += 1;
                        }
                    }
                }
            }
        }
    }
    // weights [3,3,cin,cout] flatten to [patch, cout] in the same (dy,dx,c)
    // order — the natural row-major flattening.
    let m = b * h * wd;
    dp.matmul_into(
        cols,
        weights,
        out,
        m,
        patch,
        cout,
        auto_threads(m, patch, cout),
        MatmulKernel::Auto,
    );
    for row in out.chunks_mut(cout) {
        for (v, bb) in row.iter_mut().zip(bias) {
            *v += bb;
        }
    }
}

/// 2x2 max pooling, NHWC. Allocating wrapper over [`maxpool2_into`].
#[cfg(test)]
fn maxpool2(x: &[f32], b: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0f32; b * (h / 2) * (w / 2) * c];
    maxpool2_into(x, b, h, w, c, &mut out);
    out
}

/// 2x2 max pooling, NHWC, into a caller-owned `[b, h/2, w/2, c]` buffer
/// (every cell written).
fn maxpool2_into(x: &[f32], b: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    let (oh, ow) = (h / 2, w / 2);
    assert_eq!(out.len(), b * oh * ow * c);
    for bi in 0..b {
        for y in 0..oh {
            for xx in 0..ow {
                for ch in 0..c {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let v = x[((bi * h + 2 * y + dy) * w + 2 * xx + dx) * c + ch];
                            if v > m {
                                m = v;
                            }
                        }
                    }
                    out[((bi * oh + y) * ow + xx) * c + ch] = m;
                }
            }
        }
    }
}

fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    ensure!(bytes.len() % 4 == 0, "{}: not a multiple of 4 bytes", path.display());
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{library, EXACT_ID};

    #[test]
    fn bf16_round_known_values() {
        assert_eq!(bf16_round(1.0), 1.0);
        assert_eq!(bf16_round(0.0), 0.0);
        // 1.00390625 = 1 + 2^-8 rounds to 1.0 in bf16 (RNE ties-to-even).
        assert_eq!(bf16_round(1.00390625), 1.0);
        // 1.0078125 = 1 + 2^-7 is exactly representable.
        assert_eq!(bf16_round(1.0078125), 1.0078125);
        assert_eq!(bf16_round(-2.5), -2.5);
    }

    #[test]
    fn pow2_exact_matches_f64() {
        for e in -250..=250 {
            let got = pow2_exact(e) as f64;
            let want = 2f64.powi(e);
            // Representable range of f32 (incl. denormals handled by chain).
            if (-126..=127).contains(&e) {
                assert_eq!(got, want, "e={e}");
            }
        }
    }

    #[test]
    fn exact_datapath_matches_bf16_product() {
        let lib = library();
        let dp = ApproxDatapath::new(&lib[EXACT_ID]);
        let vals = [0.0f32, 1.0, -1.5, 0.3, 7.25, -100.0, 3.1415926, 1e-3];
        for &a in &vals {
            for &b in &vals {
                let want = bf16_round(a) * bf16_round(b);
                let got = dp.mul(a, b);
                assert_eq!(got, want, "mul({a},{b})");
            }
        }
    }

    #[test]
    fn matmul_exact_lut_matches_naive() {
        let lib = library();
        let dp = ApproxDatapath::new(&lib[EXACT_ID]);
        let a: Vec<f32> = (0..6).map(|i| i as f32 * 0.5 - 1.0).collect(); // 2x3
        let b: Vec<f32> = (0..12).map(|i| (i as f32).sin()).collect(); // 3x4
        let got = dp.matmul(&a, &b, 2, 3, 4);
        for i in 0..2 {
            for j in 0..4 {
                let mut want = 0f32;
                for k in 0..3 {
                    want += bf16_round(a[i * 3 + k]) * bf16_round(b[k * 4 + j]);
                }
                assert!((got[i * 4 + j] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn scale_table_matches_pow2_exact() {
        let t = scale_table();
        assert_eq!(t.len(), 512);
        for s in 2..=510i32 {
            assert_eq!(
                t[s as usize].to_bits(),
                pow2_exact(s - 268).to_bits(),
                "exponent sum {s}"
            );
        }
    }

    #[test]
    fn sign_folded_lut_matches_mul_scalar() {
        // Single products through the table-driven path equal `mul` bitwise,
        // across signs, magnitudes, zeros, and denormals.
        let lib = library();
        for m in [&lib[EXACT_ID], &lib[5], &lib[17], lib.last().unwrap()] {
            let dp = ApproxDatapath::new(m);
            let vals = [
                0.0f32, -0.0, 1.0, -1.0, 0.3, -0.7, 7.25, -100.0, 1e-3, 1e-39, -1e-39, 3e38,
            ];
            for &a in &vals {
                for &b in &vals {
                    let got = dp.matmul(&[a], &[b], 1, 1, 1)[0];
                    let want = {
                        // Flushed products are skipped by matmul (output
                        // stays +0.0) and returned as +0.0 by mul; both add
                        // to the same accumulation.
                        let v = dp.mul(a, b);
                        0.0f32 + v
                    };
                    assert_eq!(got.to_bits(), want.to_bits(), "{}: mul({a},{b})", m.name());
                }
            }
        }
    }

    #[test]
    fn matmul_bit_identical_to_reference_prop() {
        // The tentpole oracle: BOTH row kernels — the explicit-width lane
        // kernel and the scalar fallback — must be byte-equal (`to_bits`)
        // to the retained scalar `mul` loop across multiplier families,
        // random shapes (n sweeps through every tail length), zeros,
        // denormals, and thread counts.
        let lib = library();
        // One design per family: exact, perforation, truncation,
        // broken-array, OR-compress, Mitchell, DRUM, hybrid.
        let family_ids =
            [EXACT_ID, 1, 8, 13, 21, 28, 29, lib.len() - 1];
        for (fi, &mid) in family_ids.iter().enumerate() {
            let dp = ApproxDatapath::new(&lib[mid]);
            crate::util::prop::check(&format!("matmul-bits-{mid}"), 6, |rng| {
                let (m, k, n) = (rng.range(1, 9), rng.range(1, 20), rng.range(1, 12));
                let mut sample = |len: usize| -> Vec<f32> {
                    (0..len)
                        .map(|_| match rng.below(8) {
                            0 => 0.0,
                            1 => -0.0,
                            2 => 1e-39,                      // denormal: flushed
                            3 => (rng.uniform(-3e4, 3e4)) as f32,
                            _ => (rng.uniform(-4.0, 4.0)) as f32,
                        })
                        .collect()
                };
                let a = sample(m * k);
                let b = sample(k * n);
                let want = dp.matmul_reference(&a, &b, m, k, n);
                let want_bits: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
                for threads in [1usize, 2, 3, 8] {
                    for kernel in [MatmulKernel::Lanes, MatmulKernel::Scalar] {
                        let got =
                            dp.matmul_with_kernel(&a, &b, m, k, n, threads, kernel);
                        let got_bits: Vec<u32> =
                            got.iter().map(|x| x.to_bits()).collect();
                        assert_eq!(
                            got_bits, want_bits,
                            "family #{fi} (mult {mid}), shape {m}x{k}x{n}, \
                             {threads} threads, {kernel:?} kernel"
                        );
                    }
                }
            });
        }
    }

    #[test]
    fn lane_kernel_zero_sign_semantics_match_reference() {
        // Crafted rows mixing exact cancellation (3.0 + -3.0 -> +0.0),
        // signed zeros, and flushed operands: the lane kernel's masked
        // select must leave flushed lanes' accumulators byte-untouched,
        // exactly like the scalar kernel's `continue`, so the result sign
        // bit agrees with the reference in every case.
        let lib = library();
        let dp = ApproxDatapath::new(&lib[EXACT_ID]);
        let cases: [(&[f32], &[f32]); 3] = [
            (&[1.5, -1.5, 0.0], &[2.0, 2.0, 7.0]),   // cancel then flush
            (&[-0.0, -2.0, 1e-39], &[4.0, 0.0, 3.0]), // every product flushes
            (&[-1.0, 0.0], &[0.25, -0.0]),            // lone negative + flush
        ];
        for (a, b) in cases {
            let k = a.len();
            let want = dp.matmul_reference(a, b, 1, k, 1);
            for kernel in [MatmulKernel::Lanes, MatmulKernel::Scalar] {
                let got = dp.matmul_with_kernel(a, b, 1, k, 1, 1, kernel);
                assert_eq!(got[0].to_bits(), want[0].to_bits(), "{kernel:?} {a:?}x{b:?}");
            }
        }
    }

    #[test]
    fn batched_forward_is_bit_identical_to_per_image() {
        // The batched entry point may change allocation strategy, never
        // results: logits for a 7-image batch equal the 7 single-image
        // forwards bitwise, and a reused pool equals a fresh pool.
        let mut rng = crate::util::Rng::new(0xBA7C4);
        let mut sample = |len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.uniform(-0.5, 0.5) as f32).collect()
        };
        let ne = NativeEvaluator {
            weights: Weights {
                conv1_w: sample(72),
                conv1_b: sample(8),
                conv2_w: sample(1152),
                conv2_b: sample(16),
                fc_w: sample(1280),
                fc_b: sample(5),
            },
            testset: TestSet { images: sample(7 * IMG * IMG), labels: vec![0; 7], n: 7 },
            exact_accuracy: 0.0,
        };
        let lib = library();
        for mid in [EXACT_ID, 8, lib.len() - 1] {
            let dp = ApproxDatapath::new(&lib[mid]);
            let mut buf = BatchBuffers::new(7);
            assert_eq!(buf.capacity(), 7);
            let batched: Vec<u32> = ne
                .forward_into(&dp, &ne.testset.images, 7, &mut buf)
                .iter()
                .map(|x| x.to_bits())
                .collect();
            // Per-image through the SAME (reused, now dirty) pool.
            let mut single = Vec::new();
            for i in 0..7 {
                let img = &ne.testset.images[i * IMG * IMG..(i + 1) * IMG * IMG];
                single
                    .extend(ne.forward_into(&dp, img, 1, &mut buf).iter().map(|x| x.to_bits()));
            }
            assert_eq!(batched, single, "mult {mid}: batch split changed logits");
            // And the allocating wrapper (fresh pool per call) agrees.
            let fresh: Vec<u32> = ne
                .forward(&dp, &ne.testset.images, 7)
                .iter()
                .map(|x| x.to_bits())
                .collect();
            assert_eq!(batched, fresh, "mult {mid}: pool reuse leaked state");
            // Accuracy is batch-size independent.
            let a64 = ne.accuracy_batched(&dp, 64);
            for bs in [1usize, 2, 3, 7, 100] {
                assert_eq!(ne.accuracy_batched(&dp, bs), a64, "mult {mid} bs={bs}");
            }
        }
    }

    #[test]
    fn matmul_empty_dims_are_safe() {
        let lib = library();
        let dp = ApproxDatapath::new(&lib[EXACT_ID]);
        assert!(dp.matmul(&[], &[0.0; 12], 0, 3, 4).is_empty());
        assert_eq!(dp.matmul(&[], &[], 2, 0, 3), vec![0.0; 6]);
        assert!(dp.matmul(&[1.0, 2.0], &[], 2, 1, 0).is_empty());
    }

    #[test]
    fn argmax_is_nan_safe_deterministic_first_max() {
        // Regression for the `partial_cmp(..).unwrap()` panic: NaN logits
        // must neither panic nor win, and ties resolve to the first index.
        assert_eq!(argmax(&[1.0, f32::NAN, 2.0]), 2);
        assert_eq!(argmax(&[f32::NAN, 1.0, 0.5]), 1);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[3.0, 3.0, 1.0]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::INFINITY]), 1);
        assert_eq!(argmax(&[0.25]), 0);
        assert_eq!(argmax(&[-0.0, 0.0]), 0); // -0.0 == 0.0: first wins
    }

    #[test]
    fn accuracy_survives_nan_logits() {
        // A weight set whose fc bias is NaN drives every logit to NaN; the
        // pass must yield a deterministic accuracy, not a panic.
        let n = 4usize;
        let ne = NativeEvaluator {
            weights: Weights {
                conv1_w: vec![0.0; 72],
                conv1_b: vec![0.0; 8],
                conv2_w: vec![0.0; 1152],
                conv2_b: vec![0.0; 16],
                fc_w: vec![0.0; 1280],
                fc_b: vec![f32::NAN; 5],
            },
            testset: TestSet {
                images: vec![0.5; n * IMG * IMG],
                labels: vec![0, 1, 0, 2],
                n,
            },
            exact_accuracy: 0.0,
        };
        let lib = library();
        let dp = ApproxDatapath::new(&lib[EXACT_ID]);
        // All-NaN rows argmax to class 0: exactly the label-0 images score.
        let acc = ne.accuracy(&dp);
        assert!((acc - 0.5).abs() < 1e-12, "accuracy {acc}");
    }

    #[test]
    fn truncated_datapath_underestimates_magnitude() {
        let lib = library();
        let trunc = lib.iter().find(|m| m.name() == "TRUNC4").unwrap();
        let dp_t = ApproxDatapath::new(trunc);
        let dp_e = ApproxDatapath::new(&lib[EXACT_ID]);
        for (a, b) in [(1.7f32, 2.3f32), (0.9, -0.4), (-3.3, -1.1)] {
            assert!(dp_t.mul(a, b).abs() <= dp_e.mul(a, b).abs() + 1e-9);
        }
    }

    #[test]
    fn maxpool_hand_case() {
        // 1x4x4x1 ascending values.
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let out = maxpool2(&x, 1, 4, 4, 1);
        assert_eq!(out, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn conv_identity_kernel_preserves_input() {
        // 3x3 kernel with only the center tap = 1 reproduces the input.
        let lib = library();
        let dp = ApproxDatapath::new(&lib[EXACT_ID]);
        let x: Vec<f32> = (0..16).map(|i| (i as f32) * 0.125).collect(); // 1x4x4x1
        let mut w = vec![0f32; 9];
        w[4] = 1.0; // center (dy=1,dx=1)
        let out = conv2d_same(&dp, &x, 1, 4, 4, 1, &w, &[0.0], 1);
        for (got, want) in out.iter().zip(&x) {
            assert!((got - bf16_round(*want)).abs() < 1e-6);
        }
    }
}
