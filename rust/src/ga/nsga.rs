//! NSGA-II building blocks: non-dominated sorting and crowding distance.
//!
//! The paper optimizes the scalar CDP; this module powers the *ablation*
//! (benches/ablation.rs) comparing scalar-CDP search against a true
//! multi-objective (carbon, delay) Pareto search, quantifying what the CDP
//! scalarization gives up.

/// A point in objective space (minimize both coordinates).
pub type Point = (f64, f64);

/// Does `a` dominate `b` (<= in all objectives, < in at least one)?
pub fn dominates(a: Point, b: Point) -> bool {
    (a.0 <= b.0 && a.1 <= b.1) && (a.0 < b.0 || a.1 < b.1)
}

/// Fast non-dominated sort; returns fronts as index lists (front 0 = Pareto).
pub fn non_dominated_sort(points: &[Point]) -> Vec<Vec<usize>> {
    let n = points.len();
    let mut dominated_by: Vec<usize> = vec![0; n]; // count of dominators
    let mut dominates_list: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            if dominates(points[i], points[j]) {
                dominates_list[i].push(j);
            } else if dominates(points[j], points[i]) {
                dominated_by[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| dominated_by[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominates_list[i] {
                dominated_by[j] -= 1;
                if dominated_by[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    fronts
}

/// Pareto-optimal subset of `points` (indices).
pub fn pareto_front(points: &[Point]) -> Vec<usize> {
    if points.is_empty() {
        return Vec::new();
    }
    non_dominated_sort(points).swap_remove(0)
}

/// NSGA-II crowding distance for one front (infinite at the extremes).
pub fn crowding_distance(points: &[Point], front: &[usize]) -> Vec<f64> {
    let m = front.len();
    let mut dist = vec![0.0f64; m];
    if m <= 2 {
        return vec![f64::INFINITY; m];
    }
    for obj in 0..2usize {
        let get = |i: usize| if obj == 0 { points[front[i]].0 } else { points[front[i]].1 };
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| get(a).partial_cmp(&get(b)).unwrap());
        let span = get(order[m - 1]) - get(order[0]);
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        if span <= 0.0 {
            continue;
        }
        for k in 1..m - 1 {
            dist[order[k]] += (get(order[k + 1]) - get(order[k - 1])) / span;
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn dominates_basics() {
        assert!(dominates((1.0, 1.0), (2.0, 2.0)));
        assert!(dominates((1.0, 2.0), (1.0, 3.0)));
        assert!(!dominates((1.0, 3.0), (2.0, 2.0))); // trade-off
        assert!(!dominates((1.0, 1.0), (1.0, 1.0))); // equal
    }

    #[test]
    fn sort_identifies_fronts() {
        // (0) and (1) trade off; (2) is dominated by both; (3) by (2).
        let pts = vec![(1.0, 4.0), (4.0, 1.0), (4.0, 4.0), (5.0, 5.0)];
        let fronts = non_dominated_sort(&pts);
        assert_eq!(fronts[0], vec![0, 1]);
        assert_eq!(fronts[1], vec![2]);
        assert_eq!(fronts[2], vec![3]);
    }

    #[test]
    fn pareto_front_of_chain() {
        let pts = vec![(1.0, 1.0), (2.0, 2.0), (3.0, 3.0)];
        assert_eq!(pareto_front(&pts), vec![0]);
    }

    #[test]
    fn crowding_extremes_infinite() {
        let pts = vec![(1.0, 5.0), (2.0, 4.0), (3.0, 3.0), (4.0, 2.0), (5.0, 1.0)];
        let front: Vec<usize> = (0..5).collect();
        let d = crowding_distance(&pts, &front);
        assert!(d[0].is_infinite() && d[4].is_infinite());
        assert!(d[1].is_finite() && d[2].is_finite() && d[3].is_finite());
    }

    #[test]
    fn front_members_mutually_nondominating_prop() {
        prop::check("pareto-nondominated", 30, |rng| {
            let pts: Vec<Point> =
                (0..40).map(|_| (rng.uniform(0.0, 10.0), rng.uniform(0.0, 10.0))).collect();
            let front = pareto_front(&pts);
            assert!(!front.is_empty());
            for &i in &front {
                for &j in &front {
                    if i != j {
                        assert!(!dominates(pts[i], pts[j]), "{i} dominates {j}");
                    }
                }
                // Nothing outside the front dominates a front member.
                for (k, &p) in pts.iter().enumerate() {
                    if !front.contains(&k) {
                        assert!(!dominates(p, pts[i]));
                    }
                }
            }
        });
    }

    #[test]
    fn fronts_partition_population_prop() {
        prop::check("fronts-partition", 20, |rng| {
            let pts: Vec<Point> =
                (0..30).map(|_| (rng.uniform(0.0, 5.0), rng.uniform(0.0, 5.0))).collect();
            let fronts = non_dominated_sort(&pts);
            let mut all: Vec<usize> = fronts.concat();
            all.sort_unstable();
            assert_eq!(all, (0..pts.len()).collect::<Vec<_>>());
        });
    }
}
