//! The adaptive-sampler executor: a single-threaded *planner* that
//! re-ranks the grid in seed-keyed batches by expected improvement over
//! the virtual committed front, prunes on the surrogate-tightened bound
//! ([`CostSurrogate`]), and evaluates each batch's survivors on a scoped
//! worker pool.
//!
//! **Determinism contract.** Every planner decision happens at a batch
//! boundary, as a pure function of the grid, the analytic bounds, and the
//! *virtual* state (per-family incumbents + surrogate points) replayed
//! from the rows committed so far — never of worker timing. Within a
//! batch the prune/run decisions are frozen before any evaluation starts,
//! evaluations run concurrently, and commits land in batch order through
//! [`CommitPipeline::offer_decided`]. A resumed run replays the identical
//! decision sequence: grid jobs whose rows the store already holds are
//! consumed into the virtual state without being re-offered, so the rows
//! a resume appends continue the fresh run's byte sequence exactly
//! (CI-gated by `cmp`).
//!
//! Surrogate prunes are planner-authoritative — unlike the analytic
//! incumbent rule, a learned bound is not monotone as more rows commit,
//! so the commit pipeline must trust the planner's batch-boundary verdict
//! instead of re-deriving it (`offer_decided`, not `offer`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use anyhow::{ensure, Context as _, Result};

use crate::runtime::EvalService;
use crate::util::Json;

use super::super::commit::{CommitPipeline, JobOutcome, PruneMode};
use super::super::source::{JobCtx, JobSource};
use super::super::spec::{splitmix64, JobSpec, SamplerMode};
use super::super::surrogate::{prune_rule, CostSurrogate, PruneRule};
use super::{job_context, run_job_quarantined, Executor};

/// The adaptive sampler. `batch` is the spec-fixed planning granularity
/// (recorded in the store header); `workers` only bounds evaluation
/// concurrency inside a batch and is invisible in the output bytes.
pub struct AdaptiveExecutor {
    pub workers: usize,
    pub batch: usize,
}

impl AdaptiveExecutor {
    pub fn new(workers: usize, batch: usize) -> Self {
        Self { workers, batch }
    }
}

/// Best committed objective value per job family — the planner's virtual
/// mirror of the commit pipeline's incumbent map, replayed from exactly
/// the rows (stored or fresh) the store holds.
type VirtualFront = HashMap<String, f64>;

fn virtual_update(virt: &mut VirtualFront, job: &JobSpec, obj_value: f64) {
    let e = virt.entry(job.family()).or_insert(obj_value);
    if obj_value < *e {
        *e = obj_value;
    }
}

impl Executor for AdaptiveExecutor {
    fn describe(&self) -> String {
        format!(
            "adaptive sampler (batch {}, {} worker threads)",
            self.batch.max(1),
            self.workers.max(1)
        )
    }

    fn sampler(&self) -> SamplerMode {
        SamplerMode::Adaptive { batch: self.batch }
    }

    fn drain(
        &self,
        ctx: &JobCtx,
        source: &JobSource,
        service: &EvalService,
        pipeline: &mut CommitPipeline<'_>,
    ) -> Result<()> {
        if source.schedule().is_empty() {
            // Complete store: nothing pending, and the pre-pass computed
            // no bounds — a rerun must stay a no-op.
            return Ok(());
        }
        let grid = source.grid();
        let mode = pipeline.mode();
        // Rows already in the store, by job key: the resume prefix the
        // planner consumes into virtual state instead of re-offering.
        // (Owned copy — the planner needs the pipeline mutably below.)
        let stored: HashMap<String, Option<f64>> = pipeline
            .stored_rows()
            .iter()
            .filter_map(|row| {
                let key = row.get("key").ok()?.as_str().ok()?.to_string();
                let obj = row.get("obj_value").ok().and_then(|v| v.as_f64().ok());
                Some((key, obj))
            })
            .collect();

        let mut virt: VirtualFront = HashMap::new();
        let mut surrogate = CostSurrogate::new();
        let batch_size = self.batch.max(1);
        let mut remaining: Vec<usize> = (0..grid.len()).collect();

        while !remaining.is_empty() {
            // Refit at the batch boundary, then re-rank everything still
            // undecided by expected improvement over the virtual front:
            // score = incumbent − tightened_lb (∞ for families with no
            // incumbent yet, so unexplored families are probed first).
            super::super::fault::point("surrogate.fit")?;
            surrogate.fit();
            let mut scored: Vec<(usize, f64, f64)> = remaining
                .iter()
                .map(|&gi| {
                    let job = &grid[gi];
                    let analytic = source
                        .bound(job.id)
                        .map(|b| b.objective_lb)
                        .unwrap_or(f64::NEG_INFINITY);
                    let tight = surrogate.tightened_lb(job, analytic);
                    let score = match virt.get(&job.family()) {
                        Some(&inc) => inc - tight,
                        None => f64::INFINITY,
                    };
                    (gi, score, analytic)
                })
                .collect();
            // Descending score; ties by ascending analytic bound (most
            // promising first), then a seed-derived hash, then grid id —
            // a total order, so the plan is independent of input order.
            scored.sort_by(|a, b| {
                b.1.partial_cmp(&a.1)
                    .unwrap()
                    .then(a.2.partial_cmp(&b.2).unwrap())
                    .then(splitmix64(grid[a.0].seed).cmp(&splitmix64(grid[b.0].seed)))
                    .then(grid[a.0].id.cmp(&grid[b.0].id))
            });
            let round: Vec<usize> = scored.iter().take(batch_size).map(|s| s.0).collect();
            remaining = scored.iter().skip(batch_size).map(|s| s.0).collect();
            crate::obs::metrics().incr("sampler_reranks", 1);

            // Freeze the whole batch's prune/run decisions against the
            // batch-boundary state before anything evaluates.
            let decisions: Vec<(usize, Option<JobOutcome>)> = round
                .iter()
                .map(|&gi| {
                    let job = &grid[gi];
                    let outcome = match mode {
                        PruneMode::Off => None,
                        PruneMode::Full | PruneMode::FloorOnly => {
                            // FloorOnly withholds the incumbent, which
                            // also silences the surrogate rule (it needs
                            // an incumbent to beat) — exactly the
                            // analytic executors' restriction.
                            let inc = match mode {
                                PruneMode::Full => virt.get(&job.family()).copied(),
                                _ => None,
                            };
                            match source.bound(job.id) {
                                None => None,
                                Some(bound) => match prune_rule(job, bound, inc, &surrogate) {
                                    Some(PruneRule::Surrogate) => {
                                        Some(JobOutcome::PrunedSurrogate)
                                    }
                                    Some(_) => Some(JobOutcome::Pruned),
                                    None => None,
                                },
                            }
                        }
                    };
                    (gi, outcome)
                })
                .collect();

            // Evaluate the batch's survivors that are not already stored,
            // on up to `workers` threads sharing the process service.
            let to_run: Vec<usize> = decisions
                .iter()
                .filter(|(gi, d)| d.is_none() && !stored.contains_key(&grid[*gi].key()))
                .map(|(gi, _)| *gi)
                .collect();
            let mut rows: HashMap<usize, Json> = HashMap::new();
            if !to_run.is_empty() {
                let n_workers = self.workers.max(1).min(to_run.len());
                let next = AtomicUsize::new(0);
                let (tx, rx) = mpsc::channel::<Result<(usize, Json)>>();
                std::thread::scope(|scope| -> Result<()> {
                    for _ in 0..n_workers {
                        let tx = tx.clone();
                        let client = service.client();
                        let (ctx, grid, next, to_run) = (ctx, grid, &next, &to_run);
                        scope.spawn(move || loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= to_run.len() {
                                break;
                            }
                            let gi = to_run[i];
                            // Quarantined: a panicking evaluation becomes
                            // a `failed` row the planner commits in plan
                            // order like any other (no virtual update —
                            // failed rows carry no obj_value).
                            let out = run_job_quarantined(&grid[gi], ctx, &client)
                                .with_context(|| job_context(&grid[gi]))
                                .map(|row| (gi, row));
                            if tx.send(out).is_err() {
                                break;
                            }
                        });
                    }
                    drop(tx);
                    for msg in rx {
                        let (gi, row) = msg?;
                        rows.insert(gi, row);
                    }
                    Ok(())
                })?;
            }

            // Commit the batch in plan order. Stored jobs are consumed
            // into the virtual state only — their rows are already in the
            // store and they hold no schedule slot.
            for (gi, decision) in decisions {
                let job = &grid[gi];
                let key = job.key();
                match decision {
                    Some(outcome) => {
                        ensure!(
                            !stored.contains_key(&key),
                            "adaptive replay diverged: job {key} is pruned on replay \
                             but the store holds its committed row"
                        );
                        pipeline.offer_decided(job, outcome)?;
                    }
                    None => {
                        if let Some(&obj) = stored.get(&key) {
                            if let Some(v) = obj {
                                virtual_update(&mut virt, job, v);
                                surrogate.observe(job, v);
                            }
                        } else {
                            let row = rows.remove(&gi).with_context(|| {
                                format!("batch survivor {key} was never evaluated")
                            })?;
                            let v = row.get("obj_value").ok().and_then(|x| x.as_f64().ok());
                            pipeline.offer_decided(job, JobOutcome::Row(row))?;
                            if let Some(v) = v {
                                virtual_update(&mut virt, job, v);
                                surrogate.observe(job, v);
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }
}
