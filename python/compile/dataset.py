"""Synthetic-shapes image dataset (ImageNet stand-in; see DESIGN.md §6.3).

Five classes of 16x16 grayscale images with positional jitter, random
stroke intensity and additive Gaussian noise:

  0: horizontal bar      1: vertical bar     2: cross (plus sign)
  3: square outline      4: main diagonal

The classes are chosen so a small CNN reaches high exact-path accuracy and
the margin is tight enough that approximate-multiplier error produces a
measurable, monotone-in-MRED accuracy drop — the property the paper's
multiplier-selection stage (Eq. 7) actually consumes.
"""

import numpy as np

IMG = 16
NUM_CLASSES = 5


def _render(cls: int, rng: np.random.Generator) -> np.ndarray:
    img = np.zeros((IMG, IMG), dtype=np.float32)
    c = int(rng.integers(5, IMG - 5))      # center with jitter
    r = int(rng.integers(5, IMG - 5))
    half = int(rng.integers(3, 6))         # stroke half-length
    lo_r, hi_r = max(0, r - half), min(IMG, r + half + 1)
    lo_c, hi_c = max(0, c - half), min(IMG, c + half + 1)
    amp = float(rng.uniform(0.35, 0.8))
    if cls == 0:      # horizontal bar
        img[r, lo_c:hi_c] = amp
    elif cls == 1:    # vertical bar
        img[lo_r:hi_r, c] = amp
    elif cls == 2:    # cross
        img[r, lo_c:hi_c] = amp
        img[lo_r:hi_r, c] = amp
    elif cls == 3:    # square outline
        img[lo_r, lo_c:hi_c] = amp
        img[hi_r - 1, lo_c:hi_c] = amp
        img[lo_r:hi_r, lo_c] = amp
        img[lo_r:hi_r, hi_c - 1] = amp
    elif cls == 4:    # main diagonal
        n = min(hi_r - lo_r, hi_c - lo_c)
        for t in range(n):
            img[lo_r + t, lo_c + t] = amp
    else:
        raise ValueError(f"bad class {cls}")
    return img


def generate(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Return (images [n,IMG,IMG,1] f32 in ~[0,1]+noise, labels [n] i32)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    imgs = np.stack([_render(int(c), rng) for c in labels])
    imgs += rng.normal(0.0, 0.18, size=imgs.shape).astype(np.float32)
    return imgs[..., None].astype(np.float32), labels
