//! Bench CARBON: Eq. (1)-(5) evaluation cost + full-library LUT/error
//! precomputation cost (both amortized once per process).

use carbon3d::approx::{library, lut_f32, EXACT_ID};
use carbon3d::area::die::Integration;
use carbon3d::area::TechNode;
use carbon3d::carbon::embodied_carbon;
use carbon3d::dataflow::arch::AccelConfig;
use carbon3d::obs::bench::{bench, time_once};

fn main() {
    println!("== CARBON model benches ==");
    let (lib, t_lib) = time_once(library);
    println!(
        "library(): {} designs, exhaustive error characterization in {:.3}s",
        lib.len(),
        t_lib
    );

    let cfg = AccelConfig {
        px: 32,
        py: 32,
        rf_bytes: 128,
        sram_bytes: 512 << 10,
        node: TechNode::N7,
        integration: Integration::ThreeD,
        mult_id: EXACT_ID,
    };
    let res = bench("die_areas + embodied_carbon (one config)", 100, 10_000, || {
        let areas = cfg.die_areas(&lib[EXACT_ID]);
        embodied_carbon(&areas, cfg.node, cfg.integration)
    });
    println!("{}", res.line());

    let res = bench("lut_f32 (128x128 LUT generation)", 10, 1000, || lut_f32(&lib[5]));
    println!("{}", res.line());
}
